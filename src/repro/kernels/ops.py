"""Public jit'd entry points for the DDSketch kernels.

``ddsketch_histogram`` (one sketch), ``segment_histogram`` (a bank of K
sketches), ``fold_pairs`` (the uniform-collapse resolution fold),
``ddsketch_scatter`` (the scatter stage of the sort–reduce–scatter ingest)
and ``bank_quantiles`` (the fused bank query) dispatch to the compiled
Pallas kernels on TPU and to the pure-XLA reference elsewhere.  The
semantics contracts are the ``repro.kernels.ref`` oracles; tests sweep
shapes, dtypes, mappings and tile configurations asserting exact agreement.

``force`` pins an implementation:

* ``"ref"``        — pure-XLA scatter path (any backend),
* ``"interpret"``  — interpret-mode Pallas (correctness tool, any backend),
* ``"pallas"``     — the compiled Mosaic kernel; **TPU only** (the kernel
  targets TPU tiling/VMEM — compiling it on CPU/GPU fails mid-lowering, so
  requesting it off-TPU raises immediately instead),
* ``None``         — auto: compiled kernel on TPU *when the batch fills at
  least one tile* (padding a sub-tile batch to ``value_tile`` costs more
  than the XLA scatter it replaces), reference elsewhere.

``bank_histograms`` is the bank-insert front door: it routes a batch of
``(value, segment)`` pairs to the matmul-histogram formulation (work
O(K·m·N): every output tile streams the whole batch), to the
sort–reduce–scatter pipeline (O(N log N) sort + compaction to
U <= min(N, 2·K·m) triples), or to the fused single-dispatch ingest
(``fused_ingest``: bucketize + bin + aux stats in one program) based on the
``(N, K, m)`` arithmetic-intensity ratio; ``method=`` pins a pipeline the
same way ``force=`` pins a backend, and the ``REPRO_INSERT_METHOD``
environment variable overrides the auto heuristic process-wide (benchmark
attribution / emergency pinning).
"""

from __future__ import annotations

import math
import os
import warnings

import jax
import jax.numpy as jnp

from repro.kernels.bank_quantiles import bank_quantiles_pallas
from repro.kernels.bank_range_merge import bank_range_merge_pallas
from repro.kernels.ddsketch_hist import histogram_pallas
from repro.kernels.ddsketch_ingest import ddsketch_ingest_pallas
from repro.kernels.ddsketch_scatter import MAX_RESIDENT_ROWS, ddsketch_scatter_pallas
from repro.kernels.ddsketch_seg_hist import segment_histogram_pallas
from repro.kernels.fold_pairs import fold_pairs_pallas
from repro.kernels.ref import (
    MAX_COLLAPSE_LEVEL,
    BucketSpec,
    IngestStats,
    bank_quantiles_ref,
    bank_range_merge_ref,
    compact_triples,
    composite_keys,
    fold_pairs_ref,
    fused_ingest_ref,
    histogram_ref,
    scatter_histogram_ref,
    segment_histogram_ref,
)

__all__ = [
    "ddsketch_histogram",
    "segment_histogram",
    "fold_pairs",
    "ddsketch_scatter",
    "bank_histograms",
    "fused_ingest",
    "bank_quantiles",
    "bank_range_merge",
    "insert_method",
    "dispatch_stats",
    "reset_dispatch_stats",
    "BucketSpec",
    "IngestStats",
]

_FORCE_VALUES = (None, "pallas", "interpret", "ref")
_METHOD_VALUES = (None, "matmul", "sort", "fused")
_METHOD_ENV = "REPRO_INSERT_METHOD"

# fallback observability (satellite of PR 7): auto dispatch decisions that
# silently changed paths used to be invisible — now each tall-bank
# ref-fallback warns once per call site and counts here.  Counts are per
# *trace* (the decision is made on static shapes at trace time), so an AOT
# executable that falls back registers once, not once per call.
_DISPATCH_STATS: dict[str, dict[str, int]] = {
    "tall_bank_fallbacks": {},
    # per-*trace* count of fused range-merge dispatches: the windowed-query
    # acceptance test asserts a W-slice window query registers exactly one
    # (one device program, not W-1 host-looped merges)
    "range_merge_calls": {},
    # query-path twin of tall_bank_fallbacks (satellite of PR 10): on TPU a
    # sub-tile row axis silently drops bank_quantiles / bank_range_merge off
    # the fused kernel onto the XLA reference — correct, but a perf cliff
    # the serving tier should be able to see on its dashboard
    "query_fallbacks": {},
}
_TALL_BANK_WARNED: set[str] = set()
_QUERY_WARNED: set[str] = set()


def dispatch_stats() -> dict:
    """Snapshot of auto-dispatch fallback counters (copies, safe to keep)."""
    return {k: dict(v) for k, v in _DISPATCH_STATS.items()}


def reset_dispatch_stats() -> None:
    """Clear fallback counters AND the warn-once latches (tests/benches)."""
    for v in _DISPATCH_STATS.values():
        v.clear()
    _TALL_BANK_WARNED.clear()
    _QUERY_WARNED.clear()


def _note_tall_bank_fallback(site: str, num_rows: int) -> None:
    counts = _DISPATCH_STATS["tall_bank_fallbacks"]
    counts[site] = counts.get(site, 0) + 1
    if site not in _TALL_BANK_WARNED:
        _TALL_BANK_WARNED.add(site)
        warnings.warn(
            f"{site}: bank row axis ({num_rows} rows) exceeds "
            f"MAX_RESIDENT_ROWS={MAX_RESIDENT_ROWS}; auto dispatch is "
            "falling back to the XLA reference path (correct but off the "
            "resident-row kernel).  Shard the bank, shrink it, or pin "
            'method="matmul" to silence this.  Recorded in '
            "ops.dispatch_stats(); warning once per site.",
            RuntimeWarning,
            stacklevel=3,
        )


def _note_query_fallback(site: str, num_rows: int, row_tile: int) -> None:
    """Record (and warn once) a query-path auto dispatch landing on ref.

    Counted only when the compiled kernel was on the menu (TPU backend,
    ``force=None``) and the row axis was too small to fill one tile — the
    ingest path got this treatment in PR 7; the read path gets it here so
    dashboard pollers noticing slow queries can see *why* in
    ``dispatch_stats()`` instead of guessing.
    """
    counts = _DISPATCH_STATS["query_fallbacks"]
    counts[site] = counts.get(site, 0) + 1
    if site not in _QUERY_WARNED:
        _QUERY_WARNED.add(site)
        warnings.warn(
            f"{site}: bank row axis ({num_rows} rows) is below "
            f"row_tile={row_tile}; auto dispatch is falling back to the XLA "
            "reference path (correct but off the fused query kernel).  "
            "Batch more rows per query, shrink row_tile, or pin "
            'force="ref" to acknowledge this.  Recorded in '
            "ops.dispatch_stats(); warning once per site.",
            RuntimeWarning,
            stacklevel=3,
        )


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _check_force(force: str | None) -> None:
    if force not in _FORCE_VALUES:
        raise ValueError(f"force must be one of {_FORCE_VALUES}, got {force!r}")
    if force == "pallas" and not _on_tpu():
        raise RuntimeError(
            'force="pallas" requests the compiled TPU kernel but the default '
            f"backend is {jax.default_backend()!r}; use force=\"interpret\" "
            'for correctness checks or force="ref" for the XLA fallback'
        )


def _impl(force: str | None, n: int, tile: int) -> str:
    """Resolve ``force=None`` to a concrete implementation, size-aware.

    Pinned values pass through.  Auto picks the compiled kernel only on TPU
    *and* only when the streamed axis fills at least one tile (``n >=
    tile``): below that, padding to the tile dominates the launch and the
    XLA reference is strictly cheaper.  The crossover is pinned by a unit
    test in ``tests/test_sort_scatter.py``.
    """
    if force is not None:
        return force
    if not _on_tpu() or n < tile:
        return "ref"
    return "pallas"


def insert_method(
    n: int,
    num_segments: int,
    num_buckets: int,
    unit_weights: bool = True,
    on_tpu: bool | None = None,
    full_ingest: bool = False,
) -> str:
    """Pick ``"matmul"``, ``"sort"`` or ``"fused"`` for a bank insert.

    ``full_ingest=True`` means the caller wants histogram *and* aux stats
    (``sketch_bank.add_impl``), so the fused single-dispatch path is on the
    menu; histogram-only callers (``full_ingest=False``) never auto-pick
    ``"fused"`` — its fused stats would be pure overhead there (pinning
    ``method="fused"`` still works and simply drops the stats).

    The ``REPRO_INSERT_METHOD`` environment variable overrides the
    heuristic process-wide (any of ``matmul | sort | fused``) — the
    benchmark-attribution / emergency-pinning knob; invalid values raise.

    On TPU the matmul-histogram kernel streams all N lanes through every
    ``(row_tile, bucket_tile)`` output tile — work grows with
    ``ceil(2K/TR) * ceil(m/TB)``; the sort pipeline pays N·log2(N) once and
    then streams only U <= 2·K·m compacted triples; the fused kernel keeps
    all 2K rows resident so its streamed work is ``ceil(m/TB) * N`` with no
    sort stage and no second stats pass.  Hence for full ingests with the
    rows resident, fused wins unless the bucket-tile count outgrows the
    sort factor (huge m, small N); histogram-only keeps the PR-3 sort vs
    matmul rule; banks taller than the resident-row ceiling stay on matmul.

    On the XLA reference tier the sort pipeline folds into one key pass +
    one reducing scatter; the fused path adds the stacked stats reductions
    to that same single lane pass, so for full ingests it subsumes the
    separate ``add_impl`` stats pass (measured ~1.5x over sort at N=1M,
    K=128 on CPU — ``benchmarks/bank_bench.bench_fused_ingest``).  The
    N >= 2^14 crossover vs matmul is shared: below it the batch cannot
    amortize the scatter plumbing.  ``unit_weights`` only matters for the
    TPU sort heuristic, where weighted streams must payload-sort.
    """
    env = os.environ.get(_METHOD_ENV)
    if env:
        if env not in _METHOD_VALUES[1:]:
            raise ValueError(
                f"{_METHOD_ENV}={env!r}: must be one of {_METHOD_VALUES[1:]}"
            )
        return env
    if on_tpu is None:
        on_tpu = _on_tpu()
    if n == 0:
        return "matmul"
    logn = max(math.log2(n), 1.0)
    if on_tpu:
        if 2 * num_segments > MAX_RESIDENT_ROWS:
            return "matmul"
        # weighted streams payload-sort (keys + weights move together),
        # roughly doubling the sort stage the pipeline must amortize
        sort_cost = (4.0 if unit_weights else 8.0) * logn
        if full_ingest:
            bucket_tiles = math.ceil(num_buckets / 512)
            return "fused" if bucket_tiles <= sort_cost else "sort"
        out_tiles = math.ceil(2 * num_segments / 8) * math.ceil(num_buckets / 512)
        return "sort" if out_tiles > sort_cost else "matmul"
    if n < (1 << 14):
        return "matmul"
    return "fused" if full_ingest else "sort"


def ddsketch_histogram(
    values: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    spec: BucketSpec,
    value_tile: int = 2048,
    bucket_tile: int = 512,
    force: str | None = None,  # "pallas" | "interpret" | "ref" | None(auto)
) -> jnp.ndarray:
    """Bucket counts (m,) of the positive finite entries of ``values``.

    ``levels`` holds per-value int32 collapse levels; omitted = level 0."""
    _check_force(force)
    impl = _impl(force, values.size, value_tile)
    if impl == "ref":
        return histogram_ref(values, weights, levels, spec=spec)
    return histogram_pallas(
        values,
        weights,
        levels,
        spec=spec,
        value_tile=value_tile,
        bucket_tile=bucket_tile,
        interpret=impl == "interpret",
    )


def segment_histogram(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    num_segments: int,
    spec: BucketSpec,
    value_tile: int = 2048,
    row_tile: int = 8,
    bucket_tile: int = 512,
    force: str | None = None,  # "pallas" | "interpret" | "ref" | None(auto)
) -> jnp.ndarray:
    """Per-segment bucket counts ``(num_segments, m)`` — one dispatch for a
    whole bank of K sketches regardless of K.  ``levels`` holds *per-value*
    int32 collapse levels (gather per-row levels outside); omitted = level 0."""
    _check_force(force)
    impl = _impl(force, values.size, value_tile)
    if impl == "ref":
        return segment_histogram_ref(
            values, segment_ids, weights, levels, num_segments=num_segments, spec=spec
        )
    return segment_histogram_pallas(
        values,
        segment_ids,
        weights,
        levels,
        num_segments=num_segments,
        spec=spec,
        value_tile=value_tile,
        row_tile=row_tile,
        bucket_tile=bucket_tile,
        interpret=impl == "interpret",
    )


def fold_pairs(
    counts: jnp.ndarray,
    *,
    spec: BucketSpec,
    row_tile: int = 8,
    bucket_tile: int = 512,
    force: str | None = None,  # "pallas" | "interpret" | "ref" | None(auto)
) -> jnp.ndarray:
    """One uniform-collapse fold of ``counts`` (``(K, m)`` or ``(m,)``):
    bucket pairs with keys (2j-1, 2j) merge into key j, halving the sketch
    resolution (gamma -> gamma**2).  Exact: every destination bucket sums at
    most two sources, so Pallas and XLA paths agree bit-for-bit."""
    _check_force(force)
    if force == "ref" or (force is None and not _on_tpu()):
        return fold_pairs_ref(counts, spec=spec)
    return fold_pairs_pallas(
        counts,
        spec=spec,
        row_tile=row_tile,
        bucket_tile=bucket_tile,
        interpret=force == "interpret",
    )


def ddsketch_scatter(
    keys: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    num_rows: int,
    num_buckets: int,
    triple_tile: int = 2048,
    bucket_tile: int = 512,
    force: str | None = None,  # "pallas" | "interpret" | "ref" | None(auto)
) -> jnp.ndarray:
    """Accumulate composite-key triples into ``(num_rows, num_buckets)``.

    The scatter stage of the ingest pipeline; keys outside
    ``[0, num_rows * num_buckets)`` (the compaction sentinels) contribute
    nothing.  Bit-exact vs ``ref.scatter_histogram_ref`` for unique keys —
    what ``ref.compact_triples`` emits."""
    _check_force(force)
    impl = _impl(force, keys.size, triple_tile)
    if impl != "ref" and num_rows > MAX_RESIDENT_ROWS and force is None:
        # auto never hands a too-tall bank to the resident kernel — but it
        # no longer changes paths silently (warn once + counted)
        _note_tall_bank_fallback("ddsketch_scatter", num_rows)
        impl = "ref"
    if impl == "ref":
        return scatter_histogram_ref(
            keys, weights, num_rows=num_rows, num_buckets=num_buckets
        )
    return ddsketch_scatter_pallas(
        keys,
        weights,
        num_rows=num_rows,
        num_buckets=num_buckets,
        triple_tile=triple_tile,
        bucket_tile=bucket_tile,
        interpret=impl == "interpret",
    )


def bank_histograms(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray | None = None,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    num_segments: int,
    spec: BucketSpec,
    method: str | None = None,  # "matmul" | "sort" | "fused" | None(auto)
    force: str | None = None,  # "pallas" | "interpret" | "ref" | None(auto)
    value_tile: int = 2048,
    row_tile: int = 8,
    bucket_tile: int = 512,
    triple_tile: int = 2048,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Both sign stores of a bank insert: ``(pos, neg)``, each ``(K, m)``.

    The single entry point behind ``DeviceSketch.add`` / ``SketchBank.add``:
    sign routing (positives keyed on x, negatives on ``|x|``, everything
    else contributing nothing) happens here, and ``method`` picks the
    pipeline — ``"matmul"`` masks each sign and runs the segmented
    histogram twice; ``"sort"`` is the sort–reduce–scatter ingest pipeline
    over one combined composite-key stream into the stacked ``(2K, m)``
    layout.  On the Pallas tiers the pipeline is materialized literally —
    ``ref.compact_triples`` (sort + segment-sum) feeds the
    ``ddsketch_scatter`` kernel U <= min(N, 2·K·m) unique triples — while
    the XLA twin folds the sort+reduce *into* the reducing scatter-add
    (order-free exact accumulation needs no physical sort), so the ref tier
    pays one key pass + one scatter where matmul pays two of each.

    ``method="fused"`` routes through ``fused_ingest`` (one program:
    bucketize + bin + aux stats) and drops the stats — correct anywhere,
    but the stats work is wasted on this histogram-only surface, so
    ``method=None`` never auto-picks it here (``insert_method`` only offers
    fused to ``full_ingest`` callers like ``sketch_bank.add_impl``, which
    calls ``fused_ingest`` directly to keep the stats).

    ``method=None`` auto-selects via ``insert_method``; all pipelines
    produce identical counts.  On the XLA tier the match is bit-for-bit
    for *arbitrary* weights (per output bucket the contributing lanes
    accumulate in the same order as the matmul path); on the Pallas tiers
    the unstable compaction sort reorders duplicate-key accumulation, so
    bit-exactness there holds for unit or integer-valued weights (fractional
    weights may differ in final ulps).  ``segment_ids=None`` is the
    single-sketch case (requires ``num_segments == 1``).
    """
    _check_force(force)
    if method not in _METHOD_VALUES:
        raise ValueError(f"method must be one of {_METHOD_VALUES}, got {method!r}")
    if segment_ids is None and num_segments != 1:
        raise ValueError(
            "segment_ids may be omitted only for a single-row bank "
            f"(num_segments=1), got num_segments={num_segments}"
        )
    n = int(values.size)
    if method is None:
        method = insert_method(
            n, num_segments, spec.num_buckets, unit_weights=weights is None
        )
    if method == "fused":
        pos, neg, _ = fused_ingest(
            values,
            segment_ids,
            weights,
            levels,
            num_segments=num_segments,
            spec=spec,
            bucket_tile=bucket_tile,
            force=force,
        )
        return pos, neg
    if method == "matmul":
        x = values.reshape(-1).astype(jnp.float32)
        pos_vals = jnp.where(x > spec.min_indexable, x, -1.0)
        neg_vals = jnp.where(x < -spec.min_indexable, -x, -1.0)
        if segment_ids is None:
            kw = dict(spec=spec, value_tile=value_tile, bucket_tile=bucket_tile,
                      force=force)
            pos = ddsketch_histogram(pos_vals, weights, levels, **kw)[None]
            neg = ddsketch_histogram(neg_vals, weights, levels, **kw)[None]
        else:
            kw = dict(num_segments=num_segments, spec=spec, value_tile=value_tile,
                      row_tile=row_tile, bucket_tile=bucket_tile, force=force)
            pos = segment_histogram(pos_vals, segment_ids, weights, levels, **kw)
            neg = segment_histogram(neg_vals, segment_ids, weights, levels, **kw)
        return pos, neg
    impl = _impl(force, n, triple_tile)
    if impl != "ref" and 2 * num_segments > MAX_RESIDENT_ROWS and force is None:
        # bank too tall for the resident-row scatter kernel (warn once)
        _note_tall_bank_fallback("bank_histograms[sort]", 2 * num_segments)
        impl = "ref"
    if impl == "ref":
        # XLA twin of the pipeline: scatter-add already reduces by key, so
        # the sort + segment-sum stages are the identity here — one
        # composite-key pass and one reducing scatter replace the matmul
        # path's two masked key passes and two scatters.
        keys = composite_keys(
            values, segment_ids, levels, num_segments=num_segments, spec=spec
        )
        wts = (
            jnp.ones(keys.shape, jnp.float32)
            if weights is None
            else weights.reshape(-1).astype(jnp.float32)
        )
        both = scatter_histogram_ref(
            keys, wts, num_rows=2 * num_segments, num_buckets=spec.num_buckets
        )
    else:
        keys, wts = compact_triples(
            values, segment_ids, weights, levels, num_segments=num_segments, spec=spec
        )
        # the runs are packed to the front, so the streamed axis shrinks to
        # the compacted bound min(N, 2Km + 1) — this slice is the whole
        # point of the pipeline on the kernel tiers: the scatter kernel
        # streams U-ish lanes per bucket tile, not N
        cap = min(n, 2 * num_segments * spec.num_buckets + 1)
        both = ddsketch_scatter_pallas(
            keys[:cap],
            wts[:cap],
            num_rows=2 * num_segments,
            num_buckets=spec.num_buckets,
            triple_tile=triple_tile,
            bucket_tile=bucket_tile,
            interpret=impl == "interpret",
        )
    return both[:num_segments], both[num_segments:]


def fused_ingest(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray | None = None,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    num_segments: int,
    spec: BucketSpec,
    value_tile: int = 1024,
    bucket_tile: int = 512,
    force: str | None = None,  # "pallas" | "interpret" | "ref" | None(auto)
) -> tuple[jnp.ndarray, jnp.ndarray, IngestStats]:
    """The fused single-dispatch ingest: ``(pos, neg, IngestStats)``.

    One program produces both ``(K, m)`` sign stores AND the six per-row
    aux stats (zero / overflow / underflow / summ / vmin / vmax) the bank
    maintains — ``sketch_bank.add_impl`` folds them in directly instead of
    making a second pass over the lanes.  Semantics contract is
    ``ref.fused_ingest_ref`` (histograms and the integer-weight counters
    bit-exact across tiers; the float ``summ`` may differ in final ulps on
    the Pallas tiers, where it accumulates in tile order).

    Banks whose combined pos/neg row axis exceeds ``MAX_RESIDENT_ROWS``
    fall back from the resident-row kernel to the reference (warn-once,
    counted in ``dispatch_stats()``); ``force="pallas"`` on such a bank
    raises in the kernel instead.
    """
    _check_force(force)
    impl = _impl(force, values.size, value_tile)
    if impl != "ref" and 2 * num_segments > MAX_RESIDENT_ROWS and force is None:
        _note_tall_bank_fallback("fused_ingest", 2 * num_segments)
        impl = "ref"
    if impl == "ref":
        both, stats = fused_ingest_ref(
            values, segment_ids, weights, levels,
            num_segments=num_segments, spec=spec,
        )
    else:
        both, stats = ddsketch_ingest_pallas(
            values,
            segment_ids,
            weights,
            levels,
            num_segments=num_segments,
            spec=spec,
            value_tile=value_tile,
            bucket_tile=bucket_tile,
            interpret=impl == "interpret",
        )
    return both[:num_segments], both[num_segments:], stats


def bank_quantiles(
    pos: jnp.ndarray,
    neg: jnp.ndarray,
    zero: jnp.ndarray,
    vmin: jnp.ndarray,
    vmax: jnp.ndarray,
    level: jnp.ndarray,
    qs: jnp.ndarray,
    *,
    spec: BucketSpec,
    row_tile: int = 8,
    force: str | None = None,  # "pallas" | "interpret" | "ref" | None(auto)
    table: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Fused Algorithm 2 over all K rows and all qs: ``(K, len(qs))``.

    One cumsum + lane-count searchsorted per row tile answers every q; per
    row collapse levels select the bucket-value line from the per-spec
    engine table cache.  Pallas and XLA paths share the formulation and
    agree bit-for-bit; counts of any dtype are cast to float32 for rank
    math.  ``table`` lets AOT callers (the engine) thread the per-level
    value table as an explicit executable argument instead of a closure
    constant; ``None`` fetches the engine's cached per-spec copy."""
    _check_force(force)
    if table is None:
        from repro.engine.tables import device_value_table  # deferred: no cycle

        table = device_value_table(spec)
    impl = _impl(force, pos.shape[0], row_tile)
    if impl == "ref":
        if force is None and _on_tpu():
            _note_query_fallback("bank_quantiles", pos.shape[0], row_tile)
        return bank_quantiles_ref(pos, neg, zero, vmin, vmax, level, qs, table)
    return bank_quantiles_pallas(
        pos,
        neg,
        zero,
        vmin,
        vmax,
        level,
        qs,
        table,
        row_tile=row_tile,
        interpret=impl == "interpret",
    )


def bank_range_merge(
    counts: jnp.ndarray,
    deltas: jnp.ndarray,
    *,
    spec: BucketSpec,
    valid: jnp.ndarray | None = None,
    row_tile: int = 8,
    bucket_tile: int = 512,
    force: str | None = None,  # "pallas" | "interpret" | "ref" | None(auto)
) -> jnp.ndarray:
    """Fused slice-range merge: ``counts (D, R, m), deltas (D, R) -> (R, m)``.

    The windowed-quantile tentpole: fold every slice row ``counts[d, r]``
    by ``deltas[d, r]`` uniform-collapse levels (reconciling the window's
    mixed per-row resolutions to the range max) and reduce the slice axis —
    a whole W-slice range merge in ONE dispatch, instead of W-1 host-looped
    ``sketch_bank.merge`` calls.  ``valid`` is an optional ``(D,)`` 0/1
    slice mask: dead slices contribute nothing WITHOUT their counts being
    zeroed first (masking is folded into the merge itself, saving a full
    pass over the slab).  Deltas are clipped to ``[0, MAX_COLLAPSE_LEVEL]``
    before masking.  Exact for integer-valued counts in any accumulation
    order, so Pallas and XLA paths agree bit-for-bit (contract:
    ``ref.bank_range_merge_ref``).

    Each trace increments ``dispatch_stats()["range_merge_calls"]`` — the
    one-dispatch observability hook the window tests assert on.
    """
    _check_force(force)
    calls = _DISPATCH_STATS["range_merge_calls"]
    calls["bank_range_merge"] = calls.get("bank_range_merge", 0) + 1
    impl = _impl(force, counts.shape[1], row_tile)
    if impl == "ref":
        if force is None and _on_tpu():
            _note_query_fallback("bank_range_merge", counts.shape[1], row_tile)
        return bank_range_merge_ref(counts, deltas, spec=spec, valid=valid)
    d = jnp.clip(deltas.astype(jnp.int32), 0, MAX_COLLAPSE_LEVEL)
    if valid is not None:
        # sentinel delta -1 matches no level gate in the kernel, so dead
        # slices drop out with their counts untouched
        d = jnp.where(jnp.asarray(valid).reshape(-1)[:, None] > 0, d, -1)
    return bank_range_merge_pallas(
        counts,
        d,
        spec=spec,
        row_tile=row_tile,
        bucket_tile=bucket_tile,
        interpret=impl == "interpret",
    )
