"""Pallas TPU kernel for DDSketch insertion (Algorithm 1, batched).

The paper's hot loop is ``B[ceil(log_gamma(x))] += 1`` per value.  On CPU the
reference implementations do a scalar log + hash-map increment; neither maps
to a TPU (no fast random scatter; scalar loops waste the VPU).  The
TPU-native formulation (DESIGN.md §3):

* the *mapping* is evaluated vectorized on the VPU — either a true log
  ("log" mapping) or the paper's §2.2 "costless log2 from the float's binary
  representation" trick, which lowers to integer bitcast/shift/mask ops
  ("linear"/"cubic" mappings, the DDSketch-fast variants);
* the *scatter* becomes a compare-against-iota one-hot reduction: a
  (bucket_tile, value_tile) boolean match matrix is contracted against the
  weights along the value axis.  Everything stays in VMEM/VREGs.

Grid = (bucket_tiles, value_tiles); the value axis is the innermost
(sequential reduction) dimension, so each output tile is revisited on
consecutive steps and accumulated in place, while value/weight tiles stream
through VMEM once per bucket tile.

VMEM budget per step (defaults TV=2048, TB=512, f32):
  values 8 KiB + weights 8 KiB + match matrix 4 MiB + out tile 2 KiB << 16 MiB.

Validated in interpret mode against ``repro.kernels.ref.histogram_ref``
(bit-identical float32 index math) across shapes/dtypes/mappings in
``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BucketSpec, approx_log2, shift_key

__all__ = ["histogram_pallas"]


def _hist_kernel(
    vals_ref, w_ref, lev_ref, out_ref, *, spec: BucketSpec, bucket_tile: int
):
    i = pl.program_id(0)  # bucket-tile index (parallel)
    j = pl.program_id(1)  # value-tile index (sequential reduction)

    x = vals_ref[...]  # (1, TV) float32
    w = w_ref[...]  # (1, TV) float32
    lev = lev_ref[...]  # (1, TV) int32 per-value collapse levels

    mask = jnp.isfinite(x) & (x > spec.min_indexable)
    safe = jnp.where(mask, x, 1.0)
    # ceil(log_gamma(x)) == ceil(approx_log2(x) * multiplier); float32 math
    # identical to ref.bucket_index so host/device/kernel agree exactly.
    key = jnp.ceil(approx_log2(safe, spec.mapping) * jnp.float32(spec.multiplier))
    k0 = shift_key(key.astype(jnp.int32), lev)  # collapse-level key shift
    idx = jnp.clip(k0 - spec.offset, 0, spec.num_buckets - 1)
    w = jnp.where(mask, w, 0.0)

    # one-hot match: bucket ids for this tile as rows, values as lanes
    tv = x.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bucket_tile, tv), 0)
    bucket_ids = rows + i * bucket_tile
    match = idx == bucket_ids  # (1,TV) vs (TB,TV) -> (TB,TV)
    partial = jnp.sum(jnp.where(match, w, 0.0), axis=1)[None, :]  # (1, TB)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("spec", "value_tile", "bucket_tile", "interpret")
)
def histogram_pallas(
    values: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    spec: BucketSpec,
    value_tile: int = 2048,
    bucket_tile: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Bucket-count vector (m,) for the positive finite entries of ``values``.

    Matches ``ref.histogram_ref`` exactly (same masking, same float32 index
    math); non-positive / non-finite entries contribute nothing.  ``levels``
    holds per-value collapse levels (int32, same size as ``values``); omitted
    it defaults to level 0, reproducing the uncollapsed indexing bit-for-bit.
    """
    if spec.num_buckets % bucket_tile:
        raise ValueError(
            f"num_buckets={spec.num_buckets} must be a multiple of "
            f"bucket_tile={bucket_tile}"
        )
    if values.size == 0:  # zero-length value grid would skip the tile init
        return jnp.zeros(spec.num_buckets, jnp.float32)
    x = values.reshape(-1).astype(jnp.float32)
    w = (
        jnp.ones_like(x)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    lev = (
        jnp.zeros_like(x, dtype=jnp.int32)
        if levels is None
        else levels.reshape(-1).astype(jnp.int32)
    )
    n = x.shape[0]
    pad = (-n) % value_tile
    if pad:
        x = jnp.pad(x, (0, pad), constant_values=-1.0)  # masked out in-kernel
        w = jnp.pad(w, (0, pad), constant_values=0.0)
        lev = jnp.pad(lev, (0, pad), constant_values=0)
    nv = x.shape[0] // value_tile
    nb = spec.num_buckets // bucket_tile
    x = x.reshape(nv, value_tile)
    w = w.reshape(nv, value_tile)
    lev = lev.reshape(nv, value_tile)

    out = pl.pallas_call(
        functools.partial(_hist_kernel, spec=spec, bucket_tile=bucket_tile),
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((1, value_tile), lambda i, j: (j, 0)),
            pl.BlockSpec((1, value_tile), lambda i, j: (j, 0)),
            pl.BlockSpec((1, value_tile), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bucket_tile), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bucket_tile), jnp.float32),
        interpret=interpret,
    )(x, w, lev)
    return out.reshape(spec.num_buckets)
