"""DDSketch device kernels: Pallas TPU implementations + pure-XLA oracles.

Hot spots the paper optimizes (Algorithm 1's insert loop) plus the
UDDSketch uniform-collapse fold, TPU-native:

* ``ddsketch_hist``     — single-sketch histogram insert,
* ``ddsketch_seg_hist`` — segmented insert for a bank of K sketches,
* ``fold_pairs``        — uniform-collapse resolution fold (gamma -> gamma^2),
* ``ref``               — pure-jnp semantic oracles / XLA fallback,
* ``ops``               — backend dispatch (``force=`` pins a path).
"""

from repro.kernels.ops import (  # noqa: F401
    BucketSpec,
    ddsketch_histogram,
    fold_pairs,
    segment_histogram,
)
from repro.kernels.ref import (  # noqa: F401
    MAX_COLLAPSE_LEVEL,
    fold_pairs_ref,
    histogram_ref,
    segment_histogram_ref,
)
