"""DDSketch device kernels: Pallas TPU implementations + pure-XLA oracles.

Hot spots the paper optimizes (Algorithm 1's insert loop) plus the
UDDSketch uniform-collapse fold, TPU-native:

* ``ddsketch_hist``     — single-sketch histogram insert,
* ``ddsketch_seg_hist`` — segmented insert for a bank of K sketches,
* ``ddsketch_scatter``  — input-stationary scatter over compacted triples
  (the back end of the sort–reduce–scatter ingest pipeline),
* ``ddsketch_ingest``   — fused single-dispatch full ingest: bucketize +
  bin + the six per-row aux stats in one program,
* ``bank_quantiles``    — fused cumsum + searchsorted bank query,
* ``bank_range_merge``  — fused slice-range merge for windowed quantiles
  (fold each slice row to the range's max collapse level, reduce slices),
* ``fold_pairs``        — uniform-collapse resolution fold (gamma -> gamma^2),
* ``ref``               — pure-jnp semantic oracles / XLA fallback,
* ``ops``               — backend dispatch (``force=`` pins a path,
  ``method=`` pins an insert pipeline).
"""

from repro.kernels.ops import (  # noqa: F401
    BucketSpec,
    IngestStats,
    bank_histograms,
    bank_quantiles,
    bank_range_merge,
    ddsketch_histogram,
    ddsketch_scatter,
    dispatch_stats,
    fold_pairs,
    fused_ingest,
    insert_method,
    reset_dispatch_stats,
    segment_histogram,
)
from repro.kernels.ref import (  # noqa: F401
    MAX_COLLAPSE_LEVEL,
    bank_quantiles_ref,
    bank_range_merge_ref,
    compact_triples,
    fold_pairs_ref,
    histogram_ref,
    scatter_histogram_ref,
    segment_histogram_ref,
)
