"""DDSketch device kernels: Pallas TPU implementations + pure-XLA oracles.

Hot spots the paper optimizes (Algorithm 1's insert loop), TPU-native:

* ``ddsketch_hist``     — single-sketch histogram insert,
* ``ddsketch_seg_hist`` — segmented insert for a bank of K sketches,
* ``ref``               — pure-jnp semantic oracles / XLA fallback,
* ``ops``               — backend dispatch (``force=`` pins a path).
"""

from repro.kernels.ops import (  # noqa: F401
    BucketSpec,
    ddsketch_histogram,
    segment_histogram,
)
from repro.kernels.ref import histogram_ref, segment_histogram_ref  # noqa: F401
