"""Pallas TPU kernel for segmented DDSketch insertion (a bank of K sketches).

The multi-tenant setting of the paper (one sketch per metric key: per
endpoint, per customer, per host) turns Algorithm 1 into a *segmented*
histogram: ``B[seg[v], key(x[v])] += w[v]``.  Because every sketch in the
bank shares the same data-independent bucket geometry, the bank is an
ordinary dense ``(K, m)`` array and one kernel launch fills all K rows in a
single pass over the values — the batched analogue of ``ddsketch_hist``.

Formulation (extends the compare-against-iota one-hot trick):

* per value lane, compute the bucket index exactly as ``ref.bucket_index``
  (same float32 math, so host/device/kernel agree bit-for-bit);
* the match condition becomes two one-hots,
  ``(bucket_idx == bucket_ids) & (segment_id == row_ids)``; instead of
  materializing the rank-3 ``(TR, TB, TV)`` match tensor, contract over the
  value axis with a matmul: ``A[r, v] = w[v] * (seg[v] == r)`` (TR, TV)
  against ``M[v, b] = (idx[v] == b)`` (TV, TB) — an MXU-friendly
  (TR, TV) x (TV, TB) product whose products are exact (w * {0,1}).

Grid = (row_tiles, bucket_tiles, value_tiles); the value axis is innermost
(sequential reduction), so each (row, bucket) output tile is revisited on
consecutive steps and accumulated in place in VMEM while value/weight/id
tiles stream through once per output tile.

VMEM budget per step (defaults TV=2048, TR=8, TB=512, f32):
  values+weights+ids 24 KiB + A (TR,TV) 64 KiB + M (TV,TB) 4 MiB
  + out tile (TR,TB) 16 KiB  << 16 MiB.

Validated in interpret mode against ``ref.segment_histogram_ref`` across
mappings, tile shapes, and segment counts in ``tests/test_seg_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BucketSpec, approx_log2, shift_key

__all__ = ["segment_histogram_pallas"]


def _seg_hist_kernel(
    vals_ref,
    w_ref,
    seg_ref,
    lev_ref,
    out_ref,
    *,
    spec: BucketSpec,
    row_tile: int,
    bucket_tile: int,
    num_segments: int,
):
    i = pl.program_id(0)  # row-tile index (parallel)
    j = pl.program_id(1)  # bucket-tile index (parallel)
    k = pl.program_id(2)  # value-tile index (sequential reduction)

    x = vals_ref[...]  # (1, TV) float32
    w = w_ref[...]  # (1, TV) float32
    seg = seg_ref[...]  # (1, TV) int32
    lev = lev_ref[...]  # (1, TV) int32 per-value collapse levels

    mask = (
        jnp.isfinite(x)
        & (x > spec.min_indexable)
        & (seg >= 0)
        & (seg < num_segments)
    )
    safe = jnp.where(mask, x, 1.0)
    # ceil(log_gamma(x)) == ceil(approx_log2(x) * multiplier); float32 math
    # identical to ref.bucket_index so ref/kernel agree exactly.
    key = jnp.ceil(approx_log2(safe, spec.mapping) * jnp.float32(spec.multiplier))
    k0 = shift_key(key.astype(jnp.int32), lev)  # collapse-level key shift
    idx = jnp.clip(k0 - spec.offset, 0, spec.num_buckets - 1)
    w = jnp.where(mask, w, 0.0)

    tv = x.shape[1]
    # A[r, v] = w[v] if seg[v] == global row r else 0        (TR, TV)
    rows = jax.lax.broadcasted_iota(jnp.int32, (row_tile, tv), 0) + i * row_tile
    a = jnp.where(seg == rows, w, 0.0)
    # M[v, b] = 1 if idx[v] == global bucket b else 0        (TV, TB)
    cols = (
        jax.lax.broadcasted_iota(jnp.int32, (tv, bucket_tile), 1)
        + j * bucket_tile
    )
    m = (idx.reshape(tv, 1) == cols).astype(jnp.float32)
    # contract over the value axis; products are w * {0,1} so the sum is a
    # plain weight accumulation — HIGHEST precision keeps f32 on the MXU.
    partial = jax.lax.dot_general(
        a,
        m,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST,
    )

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_segments",
        "spec",
        "value_tile",
        "row_tile",
        "bucket_tile",
        "interpret",
    ),
)
def segment_histogram_pallas(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    num_segments: int,
    spec: BucketSpec,
    value_tile: int = 2048,
    row_tile: int = 8,
    bucket_tile: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-segment bucket counts ``(num_segments, m)`` in one launch.

    Matches ``ref.segment_histogram_ref`` exactly (same masking, same
    float32 index math); non-positive / non-finite values and out-of-range
    segment ids contribute nothing.  ``num_segments`` is padded up to a
    ``row_tile`` multiple and the bucket axis up to a ``bucket_tile``
    multiple internally (pad buckets match no index, so they stay zero);
    both pads are dropped before returning.  ``levels`` holds *per-value*
    int32 collapse levels (callers with per-row levels gather
    ``row_levels[segment_ids]`` once outside); omitted it defaults to level
    0, matching the uncollapsed indexing.
    """
    if values.size != segment_ids.size:
        raise ValueError(
            f"values ({values.size} elements) and segment_ids "
            f"({segment_ids.size} elements) must have the same size"
        )
    if values.size == 0:  # zero-length value grid would skip the tile init
        return jnp.zeros((num_segments, spec.num_buckets), jnp.float32)
    x = values.reshape(-1).astype(jnp.float32)
    s = segment_ids.reshape(-1).astype(jnp.int32)
    w = (
        jnp.ones_like(x)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    lev = (
        jnp.zeros_like(s)
        if levels is None
        else levels.reshape(-1).astype(jnp.int32)
    )
    n = x.shape[0]
    pad = (-n) % value_tile
    if pad:
        x = jnp.pad(x, (0, pad), constant_values=-1.0)  # masked out in-kernel
        s = jnp.pad(s, (0, pad), constant_values=-1)
        w = jnp.pad(w, (0, pad), constant_values=0.0)
        lev = jnp.pad(lev, (0, pad), constant_values=0)
    rows_padded = num_segments + ((-num_segments) % row_tile)
    buckets_padded = spec.num_buckets + ((-spec.num_buckets) % bucket_tile)
    nv = x.shape[0] // value_tile
    nr = rows_padded // row_tile
    nb = buckets_padded // bucket_tile
    x = x.reshape(nv, value_tile)
    s = s.reshape(nv, value_tile)
    w = w.reshape(nv, value_tile)
    lev = lev.reshape(nv, value_tile)

    out = pl.pallas_call(
        functools.partial(
            _seg_hist_kernel,
            spec=spec,
            row_tile=row_tile,
            bucket_tile=bucket_tile,
            num_segments=num_segments,
        ),
        grid=(nr, nb, nv),
        in_specs=[
            pl.BlockSpec((1, value_tile), lambda i, j, k: (k, 0)),
            pl.BlockSpec((1, value_tile), lambda i, j, k: (k, 0)),
            pl.BlockSpec((1, value_tile), lambda i, j, k: (k, 0)),
            pl.BlockSpec((1, value_tile), lambda i, j, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, bucket_tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows_padded, buckets_padded), jnp.float32),
        interpret=interpret,
    )(x, w, s, lev)
    return out[:num_segments, : spec.num_buckets]
