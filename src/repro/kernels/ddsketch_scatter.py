"""Pallas TPU kernel for the scatter stage of the sort–reduce–scatter ingest.

The matmul-histogram kernels stream the *full* value array once per output
tile, so bank insert work grows as O(K·m·N) — multiplicative in the bank
size.  The ingest pipeline (``ref.compact_triples``) first sorts composite
``sign_base + seg*m + bucket`` keys and reduces duplicate runs, so only
U <= min(N, 2·K·m) unique ``(key, weight)`` triples reach the device — the
post-collapse regime UDDSketch observes (streams concentrate into a few
hundred live buckets) makes U tiny relative to N.

This kernel is the back end: accumulate the compacted triples into the
combined ``(2K, m)`` pos/neg bucket layout.  TPUs have no fast random
scatter, so the add is the same compare-against-iota trick as the histogram
kernels — but *input-stationary*: the grid runs over (bucket_tiles,
triple_tiles) only, the full bank row axis stays resident in the output
tile's sublane dimension, and each triple tile is streamed once per bucket
tile instead of once per (row, bucket) tile.  Per step, the decomposed rows
build ``A[r, t] = w[t] * (row(t) == r)`` (R, TT) against the bucket one-hot
``M[t, b] = (bucket(t) == b)`` (TT, TB); the MXU contraction accumulates the
(R, TB) output tile in place.

Because the rows are not tiled, ``rows_padded * bucket_tile`` floats must
fit in VMEM next to A and M — fine for the telemetry-bank regime (2K <=
~1024 rows); the ops dispatcher falls back to the matmul-histogram kernel
beyond that.

VMEM budget per step (defaults TT=2048, TB=512, R=256, f32):
  keys+weights 16 KiB + A (R, TT) 2 MiB + M (TT, TB) 4 MiB
  + out tile (R, TB) 512 KiB << 16 MiB.

With unique keys (what ``compact_triples`` emits) every output bucket
receives one real add plus zeros, so the kernel matches
``ref.scatter_histogram_ref`` bit-for-bit; with duplicate keys it still
accumulates exactly for integer-valued weights.  Validated in interpret
mode in ``tests/test_sort_scatter.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["MAX_RESIDENT_ROWS", "ddsketch_scatter_pallas"]

# Row ceiling keeping the resident (rows, bucket_tile) output tile + the
# (rows, triple_tile) one-hot comfortably inside VMEM at the default tiles.
MAX_RESIDENT_ROWS = 1024


def _scatter_kernel(
    keys_ref,
    w_ref,
    out_ref,
    *,
    num_rows: int,
    num_buckets: int,
    bucket_tile: int,
):
    j = pl.program_id(0)  # bucket-tile index (parallel)
    t = pl.program_id(1)  # triple-tile index (sequential reduction)

    k = keys_ref[...]  # (1, TT) int32 composite keys
    w = w_ref[...]  # (1, TT) float32 run weights

    valid = (k >= 0) & (k < num_rows * num_buckets)
    kk = jnp.where(valid, k, 0)
    r = kk // num_buckets  # combined pos/neg row in [0, 2K)
    b = kk - r * num_buckets  # bucket in [0, m)
    w = jnp.where(valid, w, 0.0)

    tt = k.shape[1]
    rows_resident = out_ref.shape[0]
    # A[rr, t] = w[t] if triple t lands in resident row rr        (R, TT)
    rr = jax.lax.broadcasted_iota(jnp.int32, (rows_resident, tt), 0)
    a = jnp.where(r == rr, w, 0.0)
    # M[t, bb] = 1 if triple t lands in global bucket bb          (TT, TB)
    cols = (
        jax.lax.broadcasted_iota(jnp.int32, (tt, bucket_tile), 1)
        + j * bucket_tile
    )
    m = (b.reshape(tt, 1) == cols).astype(jnp.float32)
    partial = jax.lax.dot_general(
        a,
        m,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST,
    )

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_rows",
        "num_buckets",
        "triple_tile",
        "bucket_tile",
        "interpret",
    ),
)
def ddsketch_scatter_pallas(
    keys: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    num_rows: int,
    num_buckets: int,
    triple_tile: int = 2048,
    bucket_tile: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Accumulate ``(key, weight)`` triples into ``(num_rows, num_buckets)``.

    Matches ``ref.scatter_histogram_ref``: keys outside
    ``[0, num_rows * num_buckets)`` contribute nothing.  The bucket axis is
    padded to a ``bucket_tile`` multiple and the row axis to the sublane
    minimum internally; pads are sliced off before returning.
    """
    if num_rows > MAX_RESIDENT_ROWS:
        raise ValueError(
            f"num_rows={num_rows} exceeds MAX_RESIDENT_ROWS="
            f"{MAX_RESIDENT_ROWS}; the scatter kernel keeps every bank row "
            "resident in VMEM — use the segmented matmul-histogram kernel "
            "for banks this tall"
        )
    if keys.size != weights.size:
        raise ValueError(
            f"keys ({keys.size} elements) and weights ({weights.size} "
            "elements) must have the same size"
        )
    if keys.size == 0:  # zero-length triple grid would skip the tile init
        return jnp.zeros((num_rows, num_buckets), jnp.float32)
    k = keys.reshape(-1).astype(jnp.int32)
    w = weights.reshape(-1).astype(jnp.float32)
    n = k.shape[0]
    pad = (-n) % triple_tile
    if pad:
        k = jnp.pad(k, (0, pad), constant_values=-1)  # masked out in-kernel
        w = jnp.pad(w, (0, pad), constant_values=0.0)
    rows_padded = num_rows + ((-num_rows) % 8)
    buckets_padded = num_buckets + ((-num_buckets) % bucket_tile)
    nt = k.shape[0] // triple_tile
    nb = buckets_padded // bucket_tile
    k = k.reshape(nt, triple_tile)
    w = w.reshape(nt, triple_tile)

    out = pl.pallas_call(
        functools.partial(
            _scatter_kernel,
            num_rows=num_rows,
            num_buckets=num_buckets,
            bucket_tile=bucket_tile,
        ),
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((1, triple_tile), lambda j, t: (t, 0)),
            pl.BlockSpec((1, triple_tile), lambda j, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((rows_padded, bucket_tile), lambda j, t: (0, j)),
        out_shape=jax.ShapeDtypeStruct((rows_padded, buckets_padded), jnp.float32),
        interpret=interpret,
    )(k, w)
    return out[:num_rows, :num_buckets]
