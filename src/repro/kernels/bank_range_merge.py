"""Pallas TPU kernel for the fused slice-range merge (windowed quantiles).

A window query over slices [i, j) of a ``(S, K, m)`` bank-ring slab must
(1) reconcile every slice row to the range's per-row max collapse level —
fold row (d, r) by ``delta[d, r] = target[r] - level[d, r]`` levels — and
(2) sum the slice axis per bucket.  Done naively that is W-1 host-looped
``merge`` dispatches (each a full collapse_to + add); here it is ONE
program over the stacked ``(D, R, m)`` counts.

Formulation: ``shift_key`` nests (ceil(ceil(k/2)/2) == ceil(k/4)), so a
``delta``-level fold is a single one-hot matrix ``F_delta[i, b] =
(ceil((offset + i)/2**delta) - offset == b)`` — the same
compare-against-iota MXU trick as ``fold_pairs``, with the fold matrix per
delta built from iotas in-kernel (never materialized in HBM).  The slice
axis D is the innermost *sequential* grid dimension: each (row-tile,
bucket-tile) output block is visited D times and accumulates

    out[r, b] += sum_delta  (delta[d, r] == delta) * (counts[d, r] @ F_delta)[b]

with the delta == 0 term taken as a direct column slice (no matmul).  The
products are counts * {0, 1}, so every accumulation is an exact f32 sum of
integer-valued counts — bit-identical to ``ref.bank_range_merge_ref`` and
to sequential ``sketch_bank.merge`` folds.

Grid = (row_tiles, bucket_tiles, D); block shapes: counts ``(1, TR, m)``,
deltas ``(1, TR, 1)``, out ``(TR, TB)`` revisited across d.

VMEM budget per step (defaults TR=8, TB=512, m=2048, f32):
  counts (TR, m) 64 KiB + F (m, TB) 4 MiB + out tile 16 KiB << 16 MiB.

Validated in interpret mode against ``ref.bank_range_merge_ref`` across
mappings, offsets, and tile shapes in ``tests/test_window_ring.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import (
    MAX_COLLAPSE_LEVEL,
    BucketSpec,
    fold_destination_range,
)

__all__ = ["bank_range_merge_pallas"]


def _range_merge_kernel(
    counts_ref, deltas_ref, out_ref, *, offset: int, bucket_tile: int
):
    j = pl.program_id(1)  # bucket-tile index (parallel)
    d = pl.program_id(2)  # slice index (sequential; accumulates into out)

    x = counts_ref[0]  # (TR, m) float32
    delta = deltas_ref[0]  # (TR, 1) int32 per-row fold depth of this slice
    m = x.shape[1]

    # delta == 0 contribution: identity fold, a direct column slice
    tile = jax.lax.dynamic_slice_in_dim(x, j * bucket_tile, bucket_tile, 1)
    acc = jnp.where(delta == 0, tile, 0.0)
    # delta >= 1 contributions: one one-hot fold matrix per level, built
    # from iotas (same exact int math as ref.multi_fold_destinations)
    src = jax.lax.broadcasted_iota(jnp.int32, (m, bucket_tile), 0)
    cols = (
        jax.lax.broadcasted_iota(jnp.int32, (m, bucket_tile), 1)
        + j * bucket_tile
    )
    for lev in range(1, MAX_COLLAPSE_LEVEL + 1):
        dst = -((-(offset + src)) >> lev) - offset  # ceil(k/2**lev) - offset
        f = (dst == cols).astype(jnp.float32)  # (m, TB) one-hot fold matrix
        folded = jax.lax.dot_general(
            x,
            f,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
            precision=jax.lax.Precision.HIGHEST,
        )
        acc = acc + jnp.where(delta == lev, folded, 0.0)

    @pl.when(d == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(d > 0)
    def _accumulate():
        out_ref[...] = out_ref[...] + acc


@functools.partial(
    jax.jit, static_argnames=("spec", "row_tile", "bucket_tile", "interpret")
)
def bank_range_merge_pallas(
    counts: jnp.ndarray,
    deltas: jnp.ndarray,
    *,
    spec: BucketSpec,
    row_tile: int = 8,
    bucket_tile: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused slice-range merge: ``counts (D, R, m), deltas (D, R) -> (R, m)``.

    Matches ``ref.bank_range_merge_ref`` bit-for-bit.  Rows are padded up
    to a ``row_tile`` multiple internally (pad rows: zero counts, delta 0)
    and dropped before returning; deltas are clipped to
    ``<= MAX_COLLAPSE_LEVEL`` only — a negative delta is the dead-slice
    sentinel and matches none of the kernel's per-level gates, so that
    slice contributes nothing without its counts being zeroed.
    """
    fold_destination_range(spec)  # static geometry check
    m = spec.num_buckets
    if spec.num_buckets % bucket_tile:
        raise ValueError(
            f"num_buckets={spec.num_buckets} must be a multiple of "
            f"bucket_tile={bucket_tile}"
        )
    if counts.ndim != 3 or counts.shape[2] != m:
        raise ValueError(f"counts must be (D, R, {m}), got {counts.shape}")
    num_slices, r = counts.shape[:2]
    if deltas.shape != (num_slices, r):
        raise ValueError(
            f"deltas must be {(num_slices, r)}, got {deltas.shape}"
        )
    x = counts.astype(jnp.float32)
    dl = jnp.minimum(deltas.astype(jnp.int32), MAX_COLLAPSE_LEVEL)
    rows_padded = r + ((-r) % row_tile)
    if rows_padded != r:
        x = jnp.pad(x, ((0, 0), (0, rows_padded - r), (0, 0)))
        dl = jnp.pad(dl, ((0, 0), (0, rows_padded - r)))
    dl = dl[:, :, None]  # (D, Rp, 1): per-row scalars ride as a lane block
    nr = rows_padded // row_tile
    nb = m // bucket_tile

    out = pl.pallas_call(
        functools.partial(
            _range_merge_kernel, offset=spec.offset, bucket_tile=bucket_tile
        ),
        grid=(nr, nb, num_slices),
        in_specs=[
            pl.BlockSpec((1, row_tile, m), lambda i, j, d: (d, i, 0)),
            pl.BlockSpec((1, row_tile, 1), lambda i, j, d: (d, i, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, bucket_tile), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows_padded, m), jnp.float32),
        interpret=interpret,
    )(x, dl)
    return out[:r]
