"""Pallas TPU kernel for the uniform-collapse fold (UDDSketch Algorithm 2).

One collapse step halves the sketch resolution: bucket pairs with keys
(2j-1, 2j) fold into key j, which squares gamma and degrades alpha to
2*alpha/(1 + alpha^2) while doubling the indexable range.  On the fixed
``(K, m)`` bank layout (bucket i holds key ``offset + i``) the fold is a
bucket-axis permute-and-pair-sum: source i goes to destination
``ceil((offset + i)/2) - offset``, and every destination receives at most
two sources — so the result is exact f32 no matter the accumulation order.

Formulation (same compare-against-iota trick as the histogram kernels):
instead of a strided gather, build the one-hot fold matrix
``F[i, b] = (dst(i) == b)`` from iotas in-kernel and contract the count
block against it on the MXU: ``out[r, b] = sum_i counts[r, i] * F[i, b]``.
The products are counts * {0,1}, so the matmul is a plain (exact) pair sum.

Grid = (row_tiles, bucket_tiles); each step loads a full-(m) row block
(TR, m) and emits one (TR, TB) output tile — no sequential accumulation.

VMEM budget per step (defaults TR=8, TB=512, m=2048, f32):
  counts (TR, m) 64 KiB + F (m, TB) 4 MiB + out tile 16 KiB << 16 MiB.

Validated in interpret mode against ``ref.fold_pairs_ref`` across offsets,
row counts, and tile shapes in ``tests/test_collapse.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BucketSpec, fold_destination_range

__all__ = ["fold_pairs_pallas"]


def _fold_kernel(counts_ref, out_ref, *, offset: int, bucket_tile: int):
    j = pl.program_id(1)  # bucket-tile index (parallel)

    x = counts_ref[...]  # (TR, m) float32
    m = x.shape[1]
    # destination index of source bucket i: ceil((offset + i)/2) - offset,
    # computed as an arithmetic shift so it matches fold_pairs_ref exactly
    src = jax.lax.broadcasted_iota(jnp.int32, (m, bucket_tile), 0)
    dst = ((offset + src + 1) >> 1) - offset
    cols = (
        jax.lax.broadcasted_iota(jnp.int32, (m, bucket_tile), 1)
        + j * bucket_tile
    )
    f = (dst == cols).astype(jnp.float32)  # (m, TB) one-hot fold matrix
    out_ref[...] = jax.lax.dot_general(
        x,
        f,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST,
    )


@functools.partial(
    jax.jit, static_argnames=("spec", "row_tile", "bucket_tile", "interpret")
)
def fold_pairs_pallas(
    counts: jnp.ndarray,
    *,
    spec: BucketSpec,
    row_tile: int = 8,
    bucket_tile: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """One uniform-collapse fold of ``counts`` (``(K, m)`` or ``(m,)``).

    Matches ``ref.fold_pairs_ref`` bit-for-bit.  Rows are padded up to a
    ``row_tile`` multiple internally; pad rows are dropped before returning.
    """
    fold_destination_range(spec)  # static geometry check
    if spec.num_buckets % bucket_tile:
        raise ValueError(
            f"num_buckets={spec.num_buckets} must be a multiple of "
            f"bucket_tile={bucket_tile}"
        )
    x = counts.reshape(-1, spec.num_buckets).astype(jnp.float32)
    k = x.shape[0]
    rows_padded = k + ((-k) % row_tile)
    if rows_padded != k:
        x = jnp.pad(x, ((0, rows_padded - k), (0, 0)))
    nr = rows_padded // row_tile
    nb = spec.num_buckets // bucket_tile

    out = pl.pallas_call(
        functools.partial(
            _fold_kernel, offset=spec.offset, bucket_tile=bucket_tile
        ),
        grid=(nr, nb),
        in_specs=[pl.BlockSpec((row_tile, spec.num_buckets), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((row_tile, bucket_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (rows_padded, spec.num_buckets), jnp.float32
        ),
        interpret=interpret,
    )(x)
    out = out[:k]
    return out.reshape(counts.shape)
