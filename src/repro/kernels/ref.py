"""Pure-jnp oracles for the DDSketch bucket kernels.

These define the *semantics* the Pallas kernels must match bit-for-bit
(same float32 index math), and serve as the XLA fallback path on hardware
without Pallas support. Shared by tests (assert_allclose vs kernels) and by
``repro.core.jax_sketch``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "BucketSpec",
    "IngestStats",
    "MAX_COLLAPSE_LEVEL",
    "bucket_index",
    "histogram_ref",
    "segment_histogram_ref",
    "fold_pairs_ref",
    "fold_destination_range",
    "approx_log2",
    "shift_key",
    "composite_keys",
    "compact_triples",
    "scatter_histogram_ref",
    "fused_ingest_ref",
    "bank_quantiles_ref",
    "bank_range_merge_ref",
    "multi_fold_destinations",
]

# Hard ceiling on the uniform-collapse level (UDDSketch, Epicoco et al. 2020).
# At level L every bucket covers gamma**(2**L); with the default geometry
# (alpha=0.01, m=2048, offset=-1024) level 3 already indexes every float32
# normal, so 6 leaves ample headroom while keeping the per-level
# bucket-value tables small trace-time constants.
MAX_COLLAPSE_LEVEL = 6


@dataclass(frozen=True)
class BucketSpec:
    """Static device-sketch geometry (trace-time constants).

    The device sketch covers keys [offset, offset + num_buckets); keys below
    collapse into bucket 0 (the static analogue of Algorithm 3's
    collapse-lowest), keys above clamp into the top bucket and are counted
    as overflow by the caller.
    """

    relative_accuracy: float = 0.01
    num_buckets: int = 2048
    offset: int = -1024  # key of bucket 0
    mapping: str = "log"  # "log" | "linear" | "cubic"

    @property
    def gamma(self) -> float:
        return (1.0 + self.relative_accuracy) / (1.0 - self.relative_accuracy)

    @property
    def multiplier(self) -> float:
        """key = ceil(_log(x) * multiplier); _log is log2-based for the
        interpolated mappings and natural-log based for "log"."""
        if self.mapping == "log":
            return 1.0 / math.log(self.gamma)
        if self.mapping == "linear":
            return 1.0 / math.log(self.gamma)
        if self.mapping == "cubic":
            from repro.core.mapping import _CUBIC_CORR

            return _CUBIC_CORR / math.log2(self.gamma)
        raise ValueError(f"unknown mapping {self.mapping}")

    @property
    def min_indexable(self) -> float:
        # float32-safe: stay inside normal range (kernels bit-cast f32)
        return 1e-37

    def key_bounds(self) -> tuple[int, int]:
        return self.offset, self.offset + self.num_buckets - 1

    def bucket_value(self, key) -> jnp.ndarray:
        """Relative-error midpoint estimate for (vector of) keys."""
        from repro.core.mapping import make_mapping

        m = make_mapping(self.mapping, self.relative_accuracy)
        import numpy as np

        keys = np.atleast_1d(np.asarray(key))
        return jnp.asarray([m.value(int(k)) for k in keys])


# --------------------------------------------------------------------- #
_CUBIC_A = 6.0 / 35.0
_CUBIC_B = -3.0 / 5.0
_CUBIC_C = 10.0 / 7.0


def approx_log2(x: jnp.ndarray, mapping: str) -> jnp.ndarray:
    """Mapping-specific monotone log approximation (float32 semantics).

    "log": exact natural log (converted by the multiplier).
    "linear"/"cubic": exponent bits + mantissa interpolation — the paper's
    §2.2 'costless log2 from the binary representation' trick, expressed as
    a bitcast so it lowers to TPU integer ops.
    """
    x = x.astype(jnp.float32)
    if mapping == "log":
        return jnp.log(x)  # natural log; multiplier = 1/ln(gamma)
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    f = (bits & 0x7FFFFF).astype(jnp.float32) * (2.0 ** -23)
    if mapping == "linear":
        return e.astype(jnp.float32) + f
    poly = ((_CUBIC_A * f + _CUBIC_B) * f + _CUBIC_C) * f
    return e.astype(jnp.float32) + poly


def shift_key(key: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Base (level-0) integer key -> collapse-level key: ceil(key / 2**level).

    Uniform collapse folds bucket pairs (2j-1, 2j) -> j, so the level-L key
    of a value is ceil(key_0 / 2**L) (ceil(ceil(y)/n) == ceil(y/n)).  The
    arithmetic right shift computes the floor for either sign, so the ceil
    is two negations — exact int32 math shared by ref and Pallas paths.
    """
    return -((-key) >> levels)


def bucket_index(
    x: jnp.ndarray, spec: BucketSpec, levels: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Clamped bucket index for positive values (callers pre-mask others).

    ``levels`` (per-value int32 collapse levels, broadcastable against x)
    shifts keys into the collapsed geometry instead of clamping base keys.
    """
    key = jnp.ceil(approx_log2(x, spec.mapping) * jnp.float32(spec.multiplier))
    k = key.astype(jnp.int32)
    if levels is not None:
        k = shift_key(k, levels)
    return jnp.clip(k - spec.offset, 0, spec.num_buckets - 1)


@partial(jax.jit, static_argnames=("spec",))
def histogram_ref(
    values: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    spec: BucketSpec,
) -> jnp.ndarray:
    """Oracle: bucket-count vector for positive finite values.

    Non-positive / non-finite entries contribute nothing (the jax_sketch
    wrapper routes them to the zero/negative/nan counters).  ``levels``
    (per-value int32 collapse levels) indexes values in the collapsed
    geometry — level 0 reproduces the base behaviour bit-for-bit.
    """
    x = values.reshape(-1).astype(jnp.float32)
    w = (
        jnp.ones_like(x)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    lev = None if levels is None else levels.reshape(-1).astype(jnp.int32)
    mask = jnp.isfinite(x) & (x > spec.min_indexable)
    idx = bucket_index(jnp.where(mask, x, 1.0), spec, lev)
    contrib = jnp.where(mask, w, 0.0)
    return jnp.zeros(spec.num_buckets, jnp.float32).at[idx].add(contrib)


@partial(jax.jit, static_argnames=("num_segments", "spec"))
def segment_histogram_ref(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    num_segments: int,
    spec: BucketSpec,
) -> jnp.ndarray:
    """Oracle: per-segment bucket counts, shape ``(num_segments, m)``.

    Row ``k`` is exactly ``histogram_ref(values[segment_ids == k])`` — one
    fixed-geometry DDSketch bucket array per segment, flattened into a single
    scatter-add so K sketches cost one XLA dispatch.  Entries whose segment
    id falls outside ``[0, num_segments)`` contribute nothing (same contract
    as the non-positive / non-finite masking).  ``levels`` holds *per-value*
    collapse levels — callers with per-row levels gather ``row_levels[s]``
    once outside (so the kernel twin needs no in-kernel gather).
    """
    x = values.reshape(-1).astype(jnp.float32)
    s = segment_ids.reshape(-1).astype(jnp.int32)
    w = (
        jnp.ones_like(x)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    lev = None if levels is None else levels.reshape(-1).astype(jnp.int32)
    mask = (
        jnp.isfinite(x)
        & (x > spec.min_indexable)
        & (s >= 0)
        & (s < num_segments)
    )
    idx = bucket_index(jnp.where(mask, x, 1.0), spec, lev)
    contrib = jnp.where(mask, w, 0.0)
    flat = jnp.clip(s, 0, num_segments - 1) * spec.num_buckets + idx
    out = jnp.zeros(num_segments * spec.num_buckets, jnp.float32).at[flat].add(contrib)
    return out.reshape(num_segments, spec.num_buckets)


# --------------------------------------------------------------------- #
# sort–reduce front end of the input-stationary ingest pipeline
# --------------------------------------------------------------------- #
def composite_keys(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray | None,
    levels: jnp.ndarray | None,
    *,
    num_segments: int,
    spec: BucketSpec,
) -> jnp.ndarray:
    """Flat ``sign_base + seg * m + bucket`` keys covering both sign stores.

    Positive values key into rows ``[0, K)`` of the combined ``(2K, m)``
    layout, negatives (keyed on ``|x|``) into rows ``[K, 2K)``, so one sort
    and one scatter cover both stores.  Lanes that contribute nothing in
    ``segment_histogram_ref`` (non-finite, ``|x| <= min_indexable``,
    out-of-range segment id) get the sentinel key ``2*K*m``, which every
    consumer drops.  The bucket index reuses the exact ``bucket_index``
    float32 math, so the pipeline agrees with the matmul-histogram path
    bit-for-bit.
    """
    m = spec.num_buckets
    sentinel = 2 * num_segments * m
    if sentinel + 1 > jnp.iinfo(jnp.int32).max:
        raise ValueError(
            f"2 * num_segments * num_buckets + 1 = {sentinel + 1} overflows "
            "int32 composite keys; shard the bank or shrink the geometry"
        )
    x = values.reshape(-1).astype(jnp.float32)
    if segment_ids is None:
        s = jnp.zeros(x.shape, jnp.int32)
    else:
        s = segment_ids.reshape(-1).astype(jnp.int32)
    lev = None if levels is None else levels.reshape(-1).astype(jnp.int32)
    finite = jnp.isfinite(x)
    is_pos = finite & (x > spec.min_indexable)
    is_neg = finite & (x < -spec.min_indexable)
    valid = (is_pos | is_neg) & (s >= 0) & (s < num_segments)
    idx = bucket_index(jnp.where(valid, jnp.abs(x), 1.0), spec, lev)
    key = (
        jnp.clip(s, 0, num_segments - 1) * m
        + idx
        + jnp.where(is_neg, num_segments * m, 0)
    )
    return jnp.where(valid, key, sentinel)


@partial(jax.jit, static_argnames=("num_segments", "spec", "payload_sort"))
def compact_triples(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray | None = None,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    num_segments: int,
    spec: BucketSpec,
    payload_sort: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort + reduce: N raw values -> U <= min(N, 2*K*m + 1) unique triples.

    Returns ``(keys, weights)`` of length N with the runs *packed to the
    front*: lanes ``0..U-1`` hold each distinct composite key of the
    combined pos/neg layout (see ``composite_keys``) once, in ascending
    order, carrying the run's total weight — invalid input lanes collapse
    into one sentinel run whose key (``2*K*m``) every consumer drops.
    Trailing lanes report int32-max keys with zero weight (also dropped).
    Because the packing is front-aligned, callers may statically slice the
    result to ``min(N, 2*K*m + 1)`` lanes — that slice is what makes the
    scatter kernel's streamed axis the *compacted* axis.

    ``weights=None`` is the fast path: only the keys are sorted (no
    payload) and run totals count lanes — exact integer math.  Explicit
    weights take the two-pass *weighted fast path*: the sort moves only
    (key, lane-index) int32 pairs — never the float weights — and the
    weights gather through the resulting permutation afterwards, so the
    heavy sort stage stays all-integer and keys-shaped for weighted
    streams too.  ``payload_sort=True`` pins the original formulation
    (the (key, weight) pairs sort together) for parity testing.  Either
    way runs reduce with an in-order ``segment_sum``; the sorts are
    unstable, so equal-key payload order is arbitrary — exact whenever
    the weights are integer-valued (the same 2^24 float32 ceiling the
    dense stores have), final-ulp differences possible between the two
    formulations for fractional weights.
    """
    m = spec.num_buckets
    key = composite_keys(
        values, segment_ids, levels, num_segments=num_segments, spec=spec
    )
    n = key.shape[0]
    if n == 0:
        return key, jnp.zeros(0, jnp.float32)
    if weights is None:
        sk = jax.lax.sort([key], num_keys=1, is_stable=False)[0]
        sw = jnp.ones_like(sk, jnp.float32)
    elif payload_sort:
        w = weights.reshape(-1).astype(jnp.float32)
        sk, sw = jax.lax.sort([key, w], num_keys=1, is_stable=False)
    else:
        w = weights.reshape(-1).astype(jnp.float32)
        perm = jax.lax.iota(jnp.int32, n)
        sk, sperm = jax.lax.sort([key, perm], num_keys=1, is_stable=False)
        sw = w[sperm]
    starts = jnp.concatenate([jnp.ones(1, bool), sk[1:] != sk[:-1]])
    rid = jnp.cumsum(starts.astype(jnp.int32)) - 1  # run index, packed 0..U-1
    run_w = jax.ops.segment_sum(sw, rid, num_segments=n, indices_are_sorted=True)
    run_k = jax.ops.segment_min(sk, rid, num_segments=n, indices_are_sorted=True)
    # empty trailing segments report int32-max keys (dropped by consumers)
    return run_k, run_w


@partial(jax.jit, static_argnames=("num_rows", "num_buckets"))
def scatter_histogram_ref(
    keys: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    num_rows: int,
    num_buckets: int,
) -> jnp.ndarray:
    """Oracle for the scatter stage: ``out[k // m, k % m] += w`` per triple.

    Keys outside ``[0, num_rows * num_buckets)`` contribute nothing (the
    compaction sentinels land here).  With unique keys — what
    ``compact_triples`` guarantees for the live lanes — every output bucket
    receives at most one add, so any correct implementation matches this
    bit-for-bit regardless of traversal order.
    """
    total = num_rows * num_buckets
    k = keys.reshape(-1)
    w = weights.reshape(-1).astype(jnp.float32)
    valid = (k >= 0) & (k < total)
    flat = jnp.where(valid, k, total)
    out = jnp.zeros(total + 1, jnp.float32).at[flat].add(jnp.where(valid, w, 0.0))
    return out[:total].reshape(num_rows, num_buckets)


# --------------------------------------------------------------------- #
# fused single-pass ingest: histogram + aux stats in one dispatch
# --------------------------------------------------------------------- #
class IngestStats(NamedTuple):
    """Per-row auxiliary statistics of one ingest batch, each ``(K,)``.

    Exactly the six non-bucket fields ``sketch_bank.add_impl`` maintains:
    the caller folds them into the bank with ``+`` (counters / sum) and
    ``minimum`` / ``maximum`` (extrema).  Rows untouched by the batch report
    0 for the counters and ``+inf`` / ``-inf`` for ``vmin`` / ``vmax`` —
    the identities of those folds.
    """

    zero: jnp.ndarray  # weight of |x| <= min_indexable lanes
    overflow: jnp.ndarray  # weight of lanes whose shifted key clamps high
    underflow: jnp.ndarray  # weight of lanes whose shifted key clamps low
    summ: jnp.ndarray  # sum of w * x over valid lanes
    vmin: jnp.ndarray  # min x over contributing (w > 0) lanes
    vmax: jnp.ndarray  # max x over contributing (w > 0) lanes


@partial(jax.jit, static_argnames=("num_segments", "spec"))
def fused_ingest_ref(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray | None = None,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    num_segments: int,
    spec: BucketSpec,
) -> tuple[jnp.ndarray, IngestStats]:
    """Oracle for the fused ingest: ``(hist (2K, m), IngestStats)`` in one pass.

    The histogram half is bit-identical to the sort pipeline's XLA twin
    (``composite_keys`` + ``scatter_histogram_ref``): positives land in rows
    ``[0, K)``, negatives (keyed on ``|x|``) in rows ``[K, 2K)``.  The stats
    half reuses the *same* elementwise key pass for the clamp accounting —
    overflow / underflow are lanes whose shifted key escapes
    ``[offset, offset + m - 1]`` — instead of a second bucketization, and
    batches the six per-row reductions into one stacked ``segment_sum``
    (zero / overflow / underflow / summ) plus one stacked ``segment_min``
    (``vmin`` and ``vmax = -min(-x)``), so the whole ingest is one read of
    the lanes where the sort path plus ``add_impl``'s stats pass reads them
    ~5x (see ``launch.roofline.ingest_bytes_model``).

    Counters are exact (sums of ``w * {0, 1}``); ``summ`` accumulates in
    lane order like ``jax.ops.segment_sum``, matching ``add_impl``'s
    segment-stats path bit-for-bit.
    """
    m = spec.num_buckets
    k = num_segments
    x = values.reshape(-1).astype(jnp.float32)
    if segment_ids is None:
        s = jnp.zeros(x.shape, jnp.int32)
    else:
        s = segment_ids.reshape(-1).astype(jnp.int32)
    w = (
        jnp.ones_like(x)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    lev = (
        jnp.zeros(x.shape, jnp.int32)
        if levels is None
        else levels.reshape(-1).astype(jnp.int32)
    )
    valid = jnp.isfinite(x) & (s >= 0) & (s < k)
    w = jnp.where(valid, w, 0.0)
    sc = jnp.clip(s, 0, max(k - 1, 0))
    is_pos = valid & (x > spec.min_indexable)
    is_neg = valid & (x < -spec.min_indexable)
    is_zero = valid & ~is_pos & ~is_neg

    # one elementwise key pass feeds the histogram AND the clamp accounting
    mag = jnp.where(is_pos | is_neg, jnp.abs(x), 1.0)
    key = jnp.ceil(approx_log2(mag, spec.mapping) * jnp.float32(spec.multiplier))
    k_lev = shift_key(key.astype(jnp.int32), lev)
    idx = jnp.clip(k_lev - spec.offset, 0, m - 1)
    top_key = spec.offset + m - 1
    over = (is_pos | is_neg) & (k_lev > top_key)
    under = (is_pos | is_neg) & (k_lev < spec.offset)

    sentinel = 2 * k * m
    flat = jnp.where(
        is_pos | is_neg, sc * m + idx + jnp.where(is_neg, k * m, 0), sentinel
    )
    hist = (
        jnp.zeros(sentinel + 1, jnp.float32)
        .at[flat]
        .add(jnp.where(is_pos | is_neg, w, 0.0))[:sentinel]
        .reshape(2 * k, m)
    )

    # stacked reductions: one segment_sum over 4 columns, one segment_min
    # over (x, -x) — six per-row stats for two passes over the lanes
    wx = w * jnp.where(valid, x, 0.0)
    sums = jax.ops.segment_sum(
        jnp.stack([w * is_zero, w * over, w * under, wx], axis=1),
        sc,
        num_segments=k,
    )
    contributes = valid & (w > 0)
    ext = jax.ops.segment_min(
        jnp.stack(
            [
                jnp.where(contributes, x, jnp.inf),
                jnp.where(contributes, -x, jnp.inf),
            ],
            axis=1,
        ),
        sc,
        num_segments=k,
    )
    stats = IngestStats(
        zero=sums[:, 0],
        overflow=sums[:, 1],
        underflow=sums[:, 2],
        summ=sums[:, 3],
        vmin=ext[:, 0],
        vmax=-ext[:, 1],
    )
    return hist, stats


# --------------------------------------------------------------------- #
# fused bank quantile query (Algorithm 2 over all rows and qs at once)
# --------------------------------------------------------------------- #
def _bank_quantiles_math(pos, neg, zero, vmin, vmax, level, qs, table, *, gather=False):
    """Shared formulation of the fused query; see ``bank_quantiles_ref``.

    Operates on a ``(K, m)`` row block with per-row scalars shaped ``(K, 1)``
    so the same code runs as the XLA oracle and inside the Pallas row-tile
    kernel (where ``K`` is the row tile).  ``qs`` is static-length; the loop
    unrolls, answering every q off one cumsum per row.

    ``gather`` switches the *selection-only* steps between bit-identical
    formulations.  The kernel keeps ``gather=False`` (masked loops, full
    lane scans, masked sums — the forms Mosaic lowers).  The XLA oracle
    uses ``gather=True``: the rank search is a per-row binary
    ``searchsorted`` (identical count on a nondecreasing cumsum) and the
    answer value is gathered straight out of ``table`` at the one
    ``(row, lane)`` each q actually reads — the dense per-level value
    plane and the mirrored value line are never materialized.  Both paths
    select the same elements, so results are bit-equal — the
    interpret-mode parity suite pins this.
    """
    num_levels = table.shape[0]
    m = pos.shape[1]
    lclip = jnp.clip(level, 0, num_levels - 1)
    if not gather:
        vals = jnp.zeros_like(pos)
        for lev in range(num_levels):
            vals = jnp.where(lclip == lev, table[lev][None, :], vals)
        line_vals = jnp.concatenate(
            [-vals[:, ::-1], jnp.zeros_like(zero), vals], axis=1
        )
    line_counts = jnp.concatenate([neg[:, ::-1], zero, pos], axis=1)
    n = jnp.sum(line_counts, axis=1, keepdims=True)
    cum = jnp.cumsum(line_counts, axis=1)
    if gather:
        search = jax.vmap(lambda c, r: jnp.searchsorted(c, r, side="right"))
        tflat = table.reshape(-1)
        lrow = lclip.reshape(-1, 1) * m  # row offset into the flat table
    else:
        lanes = jax.lax.broadcasted_iota(jnp.int32, line_counts.shape, 1)
    cols = []
    for qi in range(qs.shape[-1]):
        qf = qs.reshape(-1)[qi]
        rank = qf * jnp.maximum(n - 1.0, 0.0)
        if gather:
            idx = search(cum, rank.reshape(-1)).reshape(-1, 1)
        else:
            # searchsorted(cum, rank, side="right") == #{cum <= rank}
            idx = jnp.sum((cum <= rank).astype(jnp.int32), axis=1, keepdims=True)
        idx = jnp.clip(idx, 0, 2 * m)
        if gather:
            # line lane j maps to -vals[m-1-j] / 0 / vals[j-m-1]; read the
            # one table cell behind it instead of building the line
            vneg = -jnp.take(tflat, lrow + jnp.clip(m - 1 - idx, 0, m - 1))
            vpos = jnp.take(tflat, lrow + jnp.clip(idx - m - 1, 0, m - 1))
            est = jnp.where(idx < m, vneg, jnp.where(idx == m, 0.0, vpos))
        else:
            est = jnp.sum(
                jnp.where(lanes == idx, line_vals, 0.0), axis=1, keepdims=True
            )
        est = jnp.clip(est, vmin, vmax)  # exact-extrema clamp
        est = jnp.where(qf <= 0.0, vmin, jnp.where(qf >= 1.0, vmax, est))
        cols.append(jnp.where(n > 0, est, jnp.nan))
    return jnp.concatenate(cols, axis=1)


@jax.jit
def bank_quantiles_ref(
    pos: jnp.ndarray,
    neg: jnp.ndarray,
    zero: jnp.ndarray,
    vmin: jnp.ndarray,
    vmax: jnp.ndarray,
    level: jnp.ndarray,
    qs: jnp.ndarray,
    table: jnp.ndarray,
) -> jnp.ndarray:
    """Oracle: per-row quantiles ``(K, len(qs))`` in one fused pass.

    Semantically identical to vmapping ``jax_sketch.quantile`` over rows and
    qs (same value line, same cumsum + right-searchsorted, same extrema /
    empty-row handling), but each row's ``(2m+1)`` value line and cumsum are
    materialized once for *all* qs instead of once per (row, q) pair.
    ``table`` is the per-level bucket-value table ``(L+1, m)``; counts may be
    any dtype (cast to float32 for the rank arithmetic).
    """
    qf = jnp.atleast_1d(jnp.asarray(qs, jnp.float32))
    return _bank_quantiles_math(
        pos.astype(jnp.float32),
        neg.astype(jnp.float32),
        zero.astype(jnp.float32).reshape(-1, 1),
        vmin.reshape(-1, 1),
        vmax.reshape(-1, 1),
        level.astype(jnp.int32).reshape(-1, 1),
        qf,
        table.astype(jnp.float32),
        gather=True,
    )


# --------------------------------------------------------------------- #
# uniform collapse: fold adjacent bucket pairs (UDDSketch Algorithm 2)
# --------------------------------------------------------------------- #
def fold_destination_range(spec: BucketSpec) -> tuple[int, int]:
    """(lowest, highest) destination index of one uniform-collapse fold.

    Bucket i holds key ``offset + i``; the fold sends key k to ceil(k/2),
    i.e. index ``(offset + i + 1) // 2 - offset``.  Raises if any
    destination falls outside [0, m) — with the shipped geometries
    (offset <= 0 <= offset + m - 1) destinations always land inside.
    """
    lo = (spec.offset + 1) // 2 - spec.offset
    hi = (spec.offset + spec.num_buckets) // 2 - spec.offset
    if lo < 0 or hi > spec.num_buckets - 1:
        raise ValueError(
            f"fold_pairs destinations [{lo}, {hi}] escape the bucket array "
            f"[0, {spec.num_buckets - 1}] for offset={spec.offset}; uniform "
            "collapse needs offset <= 0 <= offset + num_buckets - 1"
        )
    return lo, hi


@partial(jax.jit, static_argnames=("spec",))
def fold_pairs_ref(counts: jnp.ndarray, *, spec: BucketSpec) -> jnp.ndarray:
    """Oracle: one uniform-collapse step over the bucket axis.

    ``counts`` is ``(..., m)``; output has the same shape with
    ``out[..., ceil((offset+i)/2) - offset] += counts[..., i]``.  Every
    destination receives at most two sources, so the result is exact in
    float32 regardless of accumulation order (the Pallas twin must match
    bit-for-bit).
    """
    fold_destination_range(spec)  # static geometry check
    m = spec.num_buckets
    keys = jnp.arange(m, dtype=jnp.int32) + spec.offset
    dst = ((keys + 1) >> 1) - spec.offset  # ceil(k/2) - offset, in [0, m)
    flat = counts.reshape(-1, m)
    out = jnp.zeros_like(flat).at[:, dst].add(flat)
    return out.reshape(counts.shape)


# --------------------------------------------------------------------- #
# fused slice-range merge: fold every slice row to its per-row target
# level and reduce the slice axis (windowed-quantile tentpole)
# --------------------------------------------------------------------- #
def multi_fold_destinations(spec: BucketSpec, delta: int):
    """Static ``(m,)`` destination indices of a ``delta``-level fold.

    ``shift_key`` nests (ceil(ceil(k/2)/2) == ceil(k/4)), so folding
    ``delta`` levels at once sends bucket i (key ``offset + i``) straight to
    ``ceil((offset + i) / 2**delta) - offset`` — identical to iterating
    ``fold_pairs_ref`` ``delta`` times.  With the shipped geometries
    (offset <= 0 <= offset + m - 1, what ``fold_destination_range``
    enforces) every destination stays inside [0, m) for any delta, which
    this asserts statically.
    """
    import numpy as np

    keys = np.arange(spec.num_buckets, dtype=np.int64) + spec.offset
    dst = -((-keys) >> delta) - spec.offset  # ceil(k / 2**delta) - offset
    if dst.min() < 0 or dst.max() > spec.num_buckets - 1:
        raise ValueError(
            f"multi-level fold (delta={delta}) destinations "
            f"[{dst.min()}, {dst.max()}] escape [0, {spec.num_buckets - 1}] "
            f"for offset={spec.offset}"
        )
    return dst.astype(np.int32)


@partial(jax.jit, static_argnames=("spec",))
def bank_range_merge_ref(
    counts: jnp.ndarray,
    deltas: jnp.ndarray,
    *,
    spec: BucketSpec,
    valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Oracle for the fused range merge: ``(D, R, m) -> (R, m)``.

    Row r of the output is the per-bucket sum of the D slice rows
    ``counts[d, r]`` after folding each one ``deltas[d, r]`` collapse
    levels — i.e. Algorithm 4's merge over the slice axis with the
    UDDSketch level reconciliation applied per (slice, row).  Callers pass
    ``deltas[d, r] = target_level[r] - level[d, r]`` (pre-clipped to
    ``[0, MAX_COLLAPSE_LEVEL]``; this clips again defensively).  ``valid``
    is an optional ``(D,)`` 0/1 slice mask: dead slices contribute
    *nothing* (their counts need not be zeroed — masking rides the
    contraction weights and a delta sentinel, never a pass over the data).

    Two runtime paths behind a ``lax.cond``:

    * **steady state** (every live delta is 0 — the common case: slice
      levels already agree with the range max): the whole merge is ONE
      mask contraction over the slice axis, a single pass over the data;
    * **reconciliation**: folds are linear, so slices are grouped by delta
      with a per-row one-hot contraction of the D axis (one data pass,
      (L, D) @ (D, m) per row), then each group is folded once —
      ``MAX_COLLAPSE_LEVEL`` static scatters total instead of one per
      (slice, delta).

    Exact for integer-valued counts in any accumulation order (the same
    2^24 float32 contract as the dense stores), so the fused result is
    bit-identical to sequential ``sketch_bank.merge`` folds; the Pallas
    twin must match this bit-for-bit.
    """
    fold_destination_range(spec)  # static geometry check
    c = counts.astype(jnp.float32)
    d = jnp.clip(deltas.astype(jnp.int32), 0, MAX_COLLAPSE_LEVEL)
    if valid is None:
        v = jnp.ones((c.shape[0],), jnp.float32)
    else:
        v = valid.astype(jnp.float32).reshape(-1)
        d = jnp.where(v[:, None] > 0, d, -1)  # sentinel: matches no level

    def steady(cc):
        # no folds anywhere: merge == one weighted sum over the slice axis
        return jnp.tensordot(v, cc, axes=1, precision=jax.lax.Precision.HIGHEST)

    def reconcile(cc):
        levels = jnp.arange(MAX_COLLAPSE_LEVEL + 1, dtype=jnp.int32)
        onehot = (d[:, :, None] == levels).astype(jnp.float32)  # (D, R, L)
        grouped = jnp.einsum(
            "drm,drl->lrm", cc, onehot, precision=jax.lax.Precision.HIGHEST
        )
        out = grouped[0]
        for delta in range(1, MAX_COLLAPSE_LEVEL + 1):
            dst = jnp.asarray(multi_fold_destinations(spec, delta))
            out = out.at[:, dst].add(grouped[delta])
        return out

    return jax.lax.cond(jnp.all(d <= 0), steady, reconcile, c)
