"""Pure-jnp oracles for the DDSketch bucket kernels.

These define the *semantics* the Pallas kernels must match bit-for-bit
(same float32 index math), and serve as the XLA fallback path on hardware
without Pallas support. Shared by tests (assert_allclose vs kernels) and by
``repro.core.jax_sketch``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "BucketSpec",
    "bucket_index",
    "histogram_ref",
    "segment_histogram_ref",
    "approx_log2",
]


@dataclass(frozen=True)
class BucketSpec:
    """Static device-sketch geometry (trace-time constants).

    The device sketch covers keys [offset, offset + num_buckets); keys below
    collapse into bucket 0 (the static analogue of Algorithm 3's
    collapse-lowest), keys above clamp into the top bucket and are counted
    as overflow by the caller.
    """

    relative_accuracy: float = 0.01
    num_buckets: int = 2048
    offset: int = -1024  # key of bucket 0
    mapping: str = "log"  # "log" | "linear" | "cubic"

    @property
    def gamma(self) -> float:
        return (1.0 + self.relative_accuracy) / (1.0 - self.relative_accuracy)

    @property
    def multiplier(self) -> float:
        """key = ceil(_log(x) * multiplier); _log is log2-based for the
        interpolated mappings and natural-log based for "log"."""
        if self.mapping == "log":
            return 1.0 / math.log(self.gamma)
        if self.mapping == "linear":
            return 1.0 / math.log(self.gamma)
        if self.mapping == "cubic":
            from repro.core.mapping import _CUBIC_CORR

            return _CUBIC_CORR / math.log2(self.gamma)
        raise ValueError(f"unknown mapping {self.mapping}")

    @property
    def min_indexable(self) -> float:
        # float32-safe: stay inside normal range (kernels bit-cast f32)
        return 1e-37

    def key_bounds(self) -> tuple[int, int]:
        return self.offset, self.offset + self.num_buckets - 1

    def bucket_value(self, key) -> jnp.ndarray:
        """Relative-error midpoint estimate for (vector of) keys."""
        from repro.core.mapping import make_mapping

        m = make_mapping(self.mapping, self.relative_accuracy)
        import numpy as np

        keys = np.atleast_1d(np.asarray(key))
        return jnp.asarray([m.value(int(k)) for k in keys])


# --------------------------------------------------------------------- #
_CUBIC_A = 6.0 / 35.0
_CUBIC_B = -3.0 / 5.0
_CUBIC_C = 10.0 / 7.0


def approx_log2(x: jnp.ndarray, mapping: str) -> jnp.ndarray:
    """Mapping-specific monotone log approximation (float32 semantics).

    "log": exact natural log (converted by the multiplier).
    "linear"/"cubic": exponent bits + mantissa interpolation — the paper's
    §2.2 'costless log2 from the binary representation' trick, expressed as
    a bitcast so it lowers to TPU integer ops.
    """
    x = x.astype(jnp.float32)
    if mapping == "log":
        return jnp.log(x)  # natural log; multiplier = 1/ln(gamma)
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    f = (bits & 0x7FFFFF).astype(jnp.float32) * (2.0 ** -23)
    if mapping == "linear":
        return e.astype(jnp.float32) + f
    poly = ((_CUBIC_A * f + _CUBIC_B) * f + _CUBIC_C) * f
    return e.astype(jnp.float32) + poly


def bucket_index(x: jnp.ndarray, spec: BucketSpec) -> jnp.ndarray:
    """Clamped bucket index for positive values (callers pre-mask others)."""
    key = jnp.ceil(approx_log2(x, spec.mapping) * jnp.float32(spec.multiplier))
    idx = key.astype(jnp.int32) - spec.offset
    return jnp.clip(idx, 0, spec.num_buckets - 1)


@partial(jax.jit, static_argnames=("spec",))
def histogram_ref(
    values: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    *,
    spec: BucketSpec,
) -> jnp.ndarray:
    """Oracle: bucket-count vector for positive finite values.

    Non-positive / non-finite entries contribute nothing (the jax_sketch
    wrapper routes them to the zero/negative/nan counters).
    """
    x = values.reshape(-1).astype(jnp.float32)
    w = (
        jnp.ones_like(x)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    mask = jnp.isfinite(x) & (x > spec.min_indexable)
    idx = bucket_index(jnp.where(mask, x, 1.0), spec)
    contrib = jnp.where(mask, w, 0.0)
    return jnp.zeros(spec.num_buckets, jnp.float32).at[idx].add(contrib)


@partial(jax.jit, static_argnames=("num_segments", "spec"))
def segment_histogram_ref(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    *,
    num_segments: int,
    spec: BucketSpec,
) -> jnp.ndarray:
    """Oracle: per-segment bucket counts, shape ``(num_segments, m)``.

    Row ``k`` is exactly ``histogram_ref(values[segment_ids == k])`` — one
    fixed-geometry DDSketch bucket array per segment, flattened into a single
    scatter-add so K sketches cost one XLA dispatch.  Entries whose segment
    id falls outside ``[0, num_segments)`` contribute nothing (same contract
    as the non-positive / non-finite masking).
    """
    x = values.reshape(-1).astype(jnp.float32)
    s = segment_ids.reshape(-1).astype(jnp.int32)
    w = (
        jnp.ones_like(x)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    mask = (
        jnp.isfinite(x)
        & (x > spec.min_indexable)
        & (s >= 0)
        & (s < num_segments)
    )
    idx = bucket_index(jnp.where(mask, x, 1.0), spec)
    contrib = jnp.where(mask, w, 0.0)
    flat = jnp.clip(s, 0, num_segments - 1) * spec.num_buckets + idx
    out = jnp.zeros(num_segments * spec.num_buckets, jnp.float32).at[flat].add(contrib)
    return out.reshape(num_segments, spec.num_buckets)
