"""Pallas TPU kernel for the fused single-dispatch bank ingest.

The sort–reduce–scatter pipeline runs as three device programs (XLA
bucketize -> XLA sort/segment-sum -> ``ddsketch_scatter`` kernel) with full
HBM round trips of the (N,)-sized intermediates between stages, and then
``sketch_bank.add_impl`` makes a *second* pass over the lanes for the aux
stats (zero / overflow / underflow / sum / extrema).  This kernel collapses
the whole ingest into **one** program: each value tile is bucketized
in-kernel (the exact ``ref.bucket_index`` float32 math, including the
per-lane uniform-collapse ``shift_key``), binned into the combined
``(2K, m)`` pos/neg layout with the input-stationary resident-row trick of
``ddsketch_scatter``, and folded into the six per-row aux stats — the lanes
are read from HBM once, ever.

Layout: grid = (bucket_tiles, value_tiles), value axis innermost
(sequential reduction).  Three outputs:

* ``hist`` ``(2K_pad, bucket_tile)`` block at ``(0, j)`` — the full bank
  row axis stays resident in VMEM (``MAX_RESIDENT_ROWS`` guard as in
  ``ddsketch_scatter``); per step the sign-routed row one-hot
  ``A[r, v] = w[v] * (row(v) == r)`` contracts against the bucket one-hot
  ``M[v, b]`` on the MXU.
* ``sums`` ``(8, K_pad)`` at ``(0, 0)`` — rows 0..3 hold zero / overflow /
  underflow / summ; accumulated additively via an ``(8, TV) x (TV, K_pad)``
  one-hot matmul, only on the ``j == 0`` sweep so each lane is counted once.
* ``ext`` ``(8, K_pad)`` at ``(0, 0)`` — rows 0..1 hold ``min(x)`` and
  ``min(-x)`` (``vmax = -min(-x)``); accumulated with ``minimum`` over the
  sublane-axis reduction of the masked ``(TV, K_pad)`` broadcast, again only
  on ``j == 0``.

VMEM budget per step (defaults TV=1024, TB=512, f32, worst-case
2K = 1024 resident rows, K_pad = 512): streams 16 KiB + A (1024, 1024)
4 MiB + M (1024, 512) 2 MiB + hist tile (1024, 512) 2 MiB + stats one-hot /
masked broadcasts 3 x (1024, 512) 6 MiB + stats tiles 32 KiB ~= 14 MiB
< 16 MiB — which is why the default ``value_tile`` here is 1024, not the
2048 the stats-free scatter kernel uses.

Counter outputs (sums of ``w * {0, 1}``) and extrema match
``ref.fused_ingest_ref`` exactly; the float ``summ`` row accumulates in
matmul/tile order instead of lane order, so it may differ from the ref in
final ulps (same caveat as the dense-stats path).  Validated in interpret
mode in ``tests/test_fused_ingest.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ddsketch_scatter import MAX_RESIDENT_ROWS
from repro.kernels.ref import BucketSpec, IngestStats, approx_log2, shift_key

__all__ = ["ddsketch_ingest_pallas"]


def _ingest_kernel(
    vals_ref,
    w_ref,
    seg_ref,
    lev_ref,
    hist_ref,
    sums_ref,
    ext_ref,
    *,
    spec: BucketSpec,
    num_segments: int,
    bucket_tile: int,
):
    j = pl.program_id(0)  # bucket-tile index (parallel)
    v = pl.program_id(1)  # value-tile index (sequential reduction)

    x = vals_ref[...]  # (1, TV) float32
    w = w_ref[...]  # (1, TV) float32
    seg = seg_ref[...]  # (1, TV) int32
    lev = lev_ref[...]  # (1, TV) int32 per-value collapse levels

    k = num_segments
    valid = jnp.isfinite(x) & (seg >= 0) & (seg < k)
    w = jnp.where(valid, w, 0.0)
    sc = jnp.clip(seg, 0, k - 1)
    is_pos = valid & (x > spec.min_indexable)
    is_neg = valid & (x < -spec.min_indexable)
    is_zero = valid & ~is_pos & ~is_neg

    # one in-register key pass: histogram index AND clamp accounting
    # (float32 math identical to ref.bucket_index, so all tiers agree)
    mag = jnp.where(is_pos | is_neg, jnp.abs(x), 1.0)
    key = jnp.ceil(approx_log2(mag, spec.mapping) * jnp.float32(spec.multiplier))
    k_lev = shift_key(key.astype(jnp.int32), lev)
    idx = jnp.clip(k_lev - spec.offset, 0, spec.num_buckets - 1)
    top_key = spec.offset + spec.num_buckets - 1
    over = (is_pos | is_neg) & (k_lev > top_key)
    under = (is_pos | is_neg) & (k_lev < spec.offset)

    tv = x.shape[1]
    rows_resident = hist_ref.shape[0]
    # sign routing into the combined (2K, m) layout: positives in rows
    # [0, K), negatives in [K, 2K)
    r = sc + jnp.where(is_neg, k, 0)
    wh = jnp.where(is_pos | is_neg, w, 0.0)
    rr = jax.lax.broadcasted_iota(jnp.int32, (rows_resident, tv), 0)
    a = jnp.where(r == rr, wh, 0.0)
    cols = (
        jax.lax.broadcasted_iota(jnp.int32, (tv, bucket_tile), 1)
        + j * bucket_tile
    )
    m1 = (idx.reshape(tv, 1) == cols).astype(jnp.float32)
    partial = jax.lax.dot_general(
        a,
        m1,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST,
    )

    @pl.when(v == 0)
    def _init_hist():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += partial

    @pl.when((j == 0) & (v == 0))
    def _init_stats():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        ext_ref[...] = jnp.full_like(ext_ref, jnp.inf)

    # stats only on the first bucket sweep: every lane counted exactly once
    @pl.when(j == 0)
    def _stats():
        kp = sums_ref.shape[1]
        kcols = jax.lax.broadcasted_iota(jnp.int32, (tv, kp), 1)
        sel = sc.reshape(tv, 1) == kcols  # (TV, KP) segment one-hot
        wx = w * jnp.where(valid, x, 0.0)
        zeros = jnp.zeros_like(x)
        data = jnp.concatenate(
            [w * is_zero, w * over, w * under, wx, zeros, zeros, zeros, zeros],
            axis=0,
        )  # (8, TV): zero / overflow / underflow / summ + sublane pad
        sums_ref[...] += jax.lax.dot_general(
            data,
            sel.astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
            precision=jax.lax.Precision.HIGHEST,
        )
        contrib = (valid & (w > 0.0)).reshape(tv, 1)
        xin = jnp.where(sel & contrib, x.reshape(tv, 1), jnp.inf)
        nxin = jnp.where(sel & contrib, -x.reshape(tv, 1), jnp.inf)
        vmin_p = jnp.min(xin, axis=0, keepdims=True)  # (1, KP)
        nmax_p = jnp.min(nxin, axis=0, keepdims=True)  # (1, KP): -vmax
        inf_row = jnp.full_like(vmin_p, jnp.inf)
        ext = jnp.concatenate(
            [vmin_p, nmax_p, inf_row, inf_row, inf_row, inf_row, inf_row,
             inf_row],
            axis=0,
        )  # (8, KP)
        ext_ref[...] = jnp.minimum(ext_ref[...], ext)


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_segments",
        "spec",
        "value_tile",
        "bucket_tile",
        "interpret",
    ),
)
def ddsketch_ingest_pallas(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray | None = None,
    weights: jnp.ndarray | None = None,
    levels: jnp.ndarray | None = None,
    *,
    num_segments: int,
    spec: BucketSpec,
    value_tile: int = 1024,
    bucket_tile: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, IngestStats]:
    """Fused bank ingest: ``(hist (2K, m), IngestStats)`` in ONE dispatch.

    Matches ``ref.fused_ingest_ref`` (counters and extrema exactly; the
    float ``summ`` up to accumulation order).  ``num_segments`` doubled (the
    combined pos/neg row axis) must fit the resident-row ceiling; the ops
    front door falls back to the reference beyond it.  The row axis is
    padded to the sublane minimum, the bucket axis to a ``bucket_tile``
    multiple, the segment axis of the stats tiles to a lane multiple, and
    the lanes to a ``value_tile`` multiple with inert fills (NaN value /
    id -1 / weight 0 / level 0); all pads are sliced off before returning.
    """
    if 2 * num_segments > MAX_RESIDENT_ROWS:
        raise ValueError(
            f"2 * num_segments = {2 * num_segments} exceeds "
            f"MAX_RESIDENT_ROWS={MAX_RESIDENT_ROWS}; the fused ingest kernel "
            "keeps the combined pos/neg row axis resident in VMEM — use the "
            "sort or matmul pipeline for banks this tall"
        )
    k = num_segments
    x = values.reshape(-1).astype(jnp.float32)
    s = (
        jnp.zeros(x.shape, jnp.int32)
        if segment_ids is None
        else segment_ids.reshape(-1).astype(jnp.int32)
    )
    w = (
        jnp.ones_like(x)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    lev = (
        jnp.zeros(x.shape, jnp.int32)
        if levels is None
        else levels.reshape(-1).astype(jnp.int32)
    )
    if x.size != s.size or x.size != w.size or x.size != lev.size:
        raise ValueError(
            f"values ({x.size}), segment_ids ({s.size}), weights ({w.size}) "
            f"and levels ({lev.size}) must have the same size"
        )
    empty_stats = IngestStats(
        zero=jnp.zeros(k, jnp.float32),
        overflow=jnp.zeros(k, jnp.float32),
        underflow=jnp.zeros(k, jnp.float32),
        summ=jnp.zeros(k, jnp.float32),
        vmin=jnp.full(k, jnp.inf, jnp.float32),
        vmax=jnp.full(k, -jnp.inf, jnp.float32),
    )
    if x.size == 0:  # zero-length value grid would skip the tile inits
        return jnp.zeros((2 * k, spec.num_buckets), jnp.float32), empty_stats
    n = x.shape[0]
    pad = (-n) % value_tile
    if pad:  # inert lanes: NaN value / id -1 / weight 0 contribute nothing
        x = jnp.pad(x, (0, pad), constant_values=jnp.nan)
        s = jnp.pad(s, (0, pad), constant_values=-1)
        w = jnp.pad(w, (0, pad), constant_values=0.0)
        lev = jnp.pad(lev, (0, pad), constant_values=0)
    rows_padded = 2 * k + ((-2 * k) % 8)
    buckets_padded = spec.num_buckets + ((-spec.num_buckets) % bucket_tile)
    k_padded = k + ((-k) % 128)  # stats lane axis
    nv = x.shape[0] // value_tile
    nb = buckets_padded // bucket_tile
    x = x.reshape(nv, value_tile)
    s = s.reshape(nv, value_tile)
    w = w.reshape(nv, value_tile)
    lev = lev.reshape(nv, value_tile)

    hist, sums, ext = pl.pallas_call(
        functools.partial(
            _ingest_kernel,
            spec=spec,
            num_segments=k,
            bucket_tile=bucket_tile,
        ),
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((1, value_tile), lambda j, v: (v, 0)),
            pl.BlockSpec((1, value_tile), lambda j, v: (v, 0)),
            pl.BlockSpec((1, value_tile), lambda j, v: (v, 0)),
            pl.BlockSpec((1, value_tile), lambda j, v: (v, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows_padded, bucket_tile), lambda j, v: (0, j)),
            pl.BlockSpec((8, k_padded), lambda j, v: (0, 0)),
            pl.BlockSpec((8, k_padded), lambda j, v: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows_padded, buckets_padded), jnp.float32),
            jax.ShapeDtypeStruct((8, k_padded), jnp.float32),
            jax.ShapeDtypeStruct((8, k_padded), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, s, lev)
    stats = IngestStats(
        zero=sums[0, :k],
        overflow=sums[1, :k],
        underflow=sums[2, :k],
        summ=sums[3, :k],
        vmin=ext[0, :k],
        vmax=-ext[1, :k],
    )
    return hist[: 2 * k, : spec.num_buckets], stats
