"""Pallas TPU kernel for the fused bank quantile query (Algorithm 2, batched).

The read side of the multi-tenant bank: answer Q quantiles for all K rows in
one launch.  The vmapped formulation rebuilt each row's ``(2m+1)``-lane
value line and cumulative counts once per (row, q) pair; here the grid runs
over row tiles, each step materializes the line and its cumsum *once* in
VMEM, and every q is answered off that cumsum with a lane-wise
compare-and-count (``#{cum <= rank}`` == right-searchsorted) plus a one-hot
value select — no gathers, no per-q rebuilds.

Per-row collapse levels select the bucket-value row from the trace-time
``(MAX_COLLAPSE_LEVEL + 1, m)`` table with a level one-hot, so mixed-level
banks query correctly in a single pass.

Grid = (row_tiles,); VMEM per step (defaults TR=8, m=2048, Q<=8, f32):
  pos+neg (TR, m) 128 KiB + table 56 KiB + line/cumsum (TR, 2m+1) 256 KiB
  << 16 MiB.

Bit-identical to ``ref.bank_quantiles_ref`` (they share the formulation in
``ref._bank_quantiles_math``); validated in interpret mode across mappings,
levels, weights, and row tiles in ``tests/test_bank_quantiles_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import _bank_quantiles_math

__all__ = ["bank_quantiles_pallas"]


def _bankq_kernel(pos_ref, neg_ref, zero_ref, vmin_ref, vmax_ref, lev_ref,
                  q_ref, table_ref, out_ref):
    out_ref[...] = _bank_quantiles_math(
        pos_ref[...],  # (TR, m)
        neg_ref[...],  # (TR, m)
        zero_ref[...],  # (TR, 1)
        vmin_ref[...],  # (TR, 1)
        vmax_ref[...],  # (TR, 1)
        lev_ref[...],  # (TR, 1) int32
        q_ref[...],  # (1, Q)
        table_ref[...],  # (L+1, m)
    )


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def bank_quantiles_pallas(
    pos: jnp.ndarray,
    neg: jnp.ndarray,
    zero: jnp.ndarray,
    vmin: jnp.ndarray,
    vmax: jnp.ndarray,
    level: jnp.ndarray,
    qs: jnp.ndarray,
    table: jnp.ndarray,
    *,
    row_tile: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-row quantile estimates ``(K, len(qs))`` in one launch.

    Matches ``ref.bank_quantiles_ref`` bit-for-bit (empty rows answer NaN,
    extremes answer vmin/vmax exactly).  Rows are padded to a ``row_tile``
    multiple internally (pad rows are empty -> NaN) and sliced off.
    """
    k, m = pos.shape
    qf = jnp.atleast_1d(jnp.asarray(qs, jnp.float32)).reshape(1, -1)
    nq = qf.shape[1]
    if k == 0:
        return jnp.zeros((0, nq), jnp.float32)
    rows_padded = k + ((-k) % row_tile)
    pad = rows_padded - k

    def rows(a, fill=0.0):
        a = a.astype(jnp.float32).reshape(k, -1)
        return jnp.pad(a, ((0, pad), (0, 0)), constant_values=fill)

    nr = rows_padded // row_tile
    out = pl.pallas_call(
        _bankq_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((row_tile, m), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, m), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, nq), lambda i: (0, 0)),
            pl.BlockSpec(table.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, nq), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_padded, nq), jnp.float32),
        interpret=interpret,
    )(
        rows(pos),
        rows(neg),
        rows(zero),
        rows(vmin, fill=jnp.inf),
        rows(vmax, fill=-jnp.inf),
        jnp.pad(level.astype(jnp.int32).reshape(k, 1), ((0, pad), (0, 0))),
        qf,
        table.astype(jnp.float32),
    )
    return out[:k]
